//! Graph data structures.

use std::collections::BTreeMap;
use std::fmt;

/// Node identifier (index into `Graph::nodes`).
pub type NodeId = usize;

/// An edge endpoint: output `port` of node `node`.
///
/// Multi-output nodes exist only after graph optimization: a convolution
/// that *forwards* its input (temporal reuse) or computes a *merged*
/// downsample (loop merge) exposes the secondary stream on port 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Edge {
    pub node: NodeId,
    pub port: u8,
}

impl Edge {
    pub fn new(node: NodeId, port: u8) -> Self {
        Edge { node, port }
    }
}

/// Role of an input edge on a consumer node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputRole {
    /// Ordinary activation stream.
    Data,
    /// Skip-connection stream that initializes the accumulator
    /// (paper Fig. 13, produced by the add-fusion pass).
    SkipInit,
}

/// A pointwise downsample convolution absorbed into another conv's task by
/// the loop-merge pass (paper Fig. 12b).  Reads the same input stream as
/// the host conv; its output appears on the host's port 1.
#[derive(Debug, Clone, PartialEq)]
pub struct MergedDownsample {
    /// Original layer name (weights are looked up under this name).
    pub name: String,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub w_exp: i32,
    pub out_exp: i32,
}

/// Convolution attributes (geometry + quantization exponents).
#[derive(Debug, Clone, PartialEq)]
pub struct ConvAttrs {
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// ReLU fused into the accumulator path (set by the relu-merge pass or
    /// directly by the optimized builder).
    pub relu: bool,
    /// Weight exponent (power-of-two scale).
    pub w_exp: i32,
    /// Output activation exponent.
    pub out_exp: i32,
    /// Loop merge (paper Fig. 12b): this conv also computes a pointwise
    /// downsample convolution over the same input inside the same task.
    pub merged_downsample: Option<MergedDownsample>,
    /// Temporal reuse (paper Fig. 12a): this conv re-emits its input
    /// activations on output port 1 once its window buffer has fully used
    /// them, so the skip branch needs no second buffer.
    pub forwards_input: bool,
    /// Emit raw int32 accumulators (no requantize/clip) — the naive
    /// residual dataflow streams 32-bit partials into the Add node so the
    /// merge is exact; add fusion clears this when it absorbs the Add.
    pub raw_output: bool,
}

/// BatchNorm attributes (float; exists only pre-fold, as in the paper where
/// BN is merged into the quantized convolutions after training).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchNormAttrs {
    pub channels: usize,
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
}

/// Operation kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Network input (DMA stream from off-chip memory).
    Input { h: usize, w: usize, c: usize, exp: i32 },
    Conv(ConvAttrs),
    BatchNorm(BatchNormAttrs),
    Relu,
    /// Residual merge node (pre-optimization only; removed by add fusion).
    Add { out_exp: i32 },
    MaxPool { k: usize, stride: usize },
    /// Global average pool (power-of-two window -> shift divide).
    GlobalAvgPool { out_exp: i32 },
    Linear { cin: usize, cout: usize, w_exp: i32 },
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv(_) => "conv",
            Op::BatchNorm(_) => "batchnorm",
            Op::Relu => "relu",
            Op::Add { .. } => "add",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool { .. } => "gap",
            Op::Linear { .. } => "linear",
        }
    }
}

/// A graph node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    /// Input edges with roles, in positional order.
    pub inputs: Vec<(Edge, InputRole)>,
    /// Logically deleted (passes mark-and-sweep; `compact` drops these).
    pub dead: bool,
}

/// The network graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: Vec<(Edge, InputRole)>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.into(), op, inputs, dead: false });
        id
    }

    pub fn add_simple(&mut self, name: impl Into<String>, op: Op, inputs: &[Edge]) -> NodeId {
        self.add(name, op, inputs.iter().map(|&e| (e, InputRole::Data)).collect())
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id]
    }

    /// Live nodes in id order (ids are already topological: nodes can only
    /// reference earlier nodes, enforced by `add`'s usage pattern and
    /// checked by `validate`).
    pub fn live(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| !n.dead)
    }

    /// All live consumers of `edge`.
    pub fn consumers(&self, edge: Edge) -> Vec<NodeId> {
        self.live()
            .filter(|n| n.inputs.iter().any(|(e, _)| *e == edge))
            .map(|n| n.id)
            .collect()
    }

    /// Find a live node by name.
    pub fn find(&self, name: &str) -> Option<NodeId> {
        self.live().find(|n| n.name == name).map(|n| n.id)
    }

    /// Number of live nodes.
    pub fn len_live(&self) -> usize {
        self.live().count()
    }

    /// The unique live node with no live consumers (the network output).
    pub fn output(&self) -> Option<NodeId> {
        let mut sinks: Vec<NodeId> = self
            .live()
            .filter(|n| {
                !self
                    .live()
                    .any(|m| m.inputs.iter().any(|(e, _)| e.node == n.id))
            })
            .map(|n| n.id)
            .collect();
        if sinks.len() == 1 {
            sinks.pop()
        } else {
            None
        }
    }

    /// Structural validation: edges reference earlier live nodes, ports are
    /// in range, input arities match op kinds.
    pub fn validate(&self) -> Result<(), String> {
        for n in self.live() {
            for (e, _) in &n.inputs {
                if e.node >= n.id {
                    return Err(format!("node {} ({}) has non-topological input {}", n.id, n.name, e.node));
                }
                let src = &self.nodes[e.node];
                if src.dead {
                    return Err(format!("node {} reads dead node {}", n.name, src.name));
                }
                let max_port = match &src.op {
                    Op::Conv(c) if c.forwards_input || c.merged_downsample.is_some() => 1,
                    _ => 0,
                };
                if e.port as usize > max_port {
                    return Err(format!("node {} reads port {} of {}", n.name, e.port, src.name));
                }
            }
            // Two operands on the same producer edge would collide in the
            // streaming planner's per-(edge, consumer) FIFO map: both
            // operands resolve to one FIFO, which is then popped twice
            // while a second, never-drained FIFO fills — a guaranteed
            // runtime stall.  Reject statically; a doubled tensor belongs
            // upstream (scale it), not as duplicate merge operands.
            for (i, (ea, _)) in n.inputs.iter().enumerate() {
                for (eb, _) in &n.inputs[i + 1..] {
                    if ea == eb {
                        return Err(format!(
                            "node {} ({}) reads duplicate input edge {}:{}",
                            n.name, n.op.kind(), self.nodes[ea.node].name, ea.port
                        ));
                    }
                }
            }
            let arity = n.inputs.len();
            let ok = match &n.op {
                Op::Input { .. } => arity == 0,
                Op::Conv(_) => (1..=2).contains(&arity),
                Op::BatchNorm(_) | Op::Relu | Op::MaxPool { .. } | Op::GlobalAvgPool { .. } => arity == 1,
                // Residual merges take the long branch plus >= 1 skip
                // operand; multi-input adds (several skips converging on
                // one merge) are general skip-graph topologies.
                Op::Add { .. } => arity >= 2,
                Op::Linear { .. } => arity == 1,
            };
            if !ok {
                return Err(format!("node {} ({}) has arity {}", n.name, n.op.kind(), arity));
            }
        }
        Ok(())
    }

    /// Remove dead nodes, remapping ids (returns old->new id map).
    pub fn compact(&mut self) -> BTreeMap<NodeId, NodeId> {
        let mut map = BTreeMap::new();
        let mut new_nodes = Vec::new();
        for n in self.nodes.drain(..) {
            if n.dead {
                continue;
            }
            let new_id = new_nodes.len();
            map.insert(n.id, new_id);
            new_nodes.push(Node { id: new_id, ..n });
        }
        for n in &mut new_nodes {
            for (e, _) in &mut n.inputs {
                e.node = map[&e.node];
            }
        }
        self.nodes = new_nodes;
        map
    }

    /// Count live nodes of a given kind.
    pub fn count_kind(&self, kind: &str) -> usize {
        self.live().filter(|n| n.op.kind() == kind).count()
    }
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for n in self.live() {
            let ins: Vec<String> = n
                .inputs
                .iter()
                .map(|(e, r)| {
                    let tag = if *r == InputRole::SkipInit { ":skip" } else { "" };
                    if e.port == 0 {
                        format!("{}{}", self.nodes[e.node].name, tag)
                    } else {
                        format!("{}.{}{}", self.nodes[e.node].name, e.port, tag)
                    }
                })
                .collect();
            writeln!(f, "{:>3} {:<10} {:<9} <- [{}]", n.id, n.name, n.op.kind(), ins.join(", "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 8, w: 8, c: 3, exp: -7 }, &[]);
        let c = g.add_simple(
            "conv",
            Op::Conv(ConvAttrs {
                cin: 3, cout: 4, k: 3, stride: 1, pad: 1, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        g.add_simple("relu", Op::Relu, &[Edge::new(c, 0)]);
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.output(), g.find("relu"));
        assert_eq!(g.consumers(Edge::new(g.find("conv").unwrap(), 0)).len(), 1);
    }

    #[test]
    fn compact_remaps() {
        let mut g = tiny();
        let relu = g.find("relu").unwrap();
        let conv = g.find("conv").unwrap();
        // kill relu, rewire nothing (conv becomes sink)
        g.node_mut(relu).dead = true;
        let map = g.compact();
        assert_eq!(g.nodes.len(), 2);
        assert!(g.validate().is_ok());
        assert_eq!(map[&conv], 1);
        assert_eq!(g.output(), Some(1));
    }

    #[test]
    fn validate_catches_bad_arity() {
        let mut g = tiny();
        let relu = g.find("relu").unwrap();
        g.node_mut(relu).inputs.clear();
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_duplicate_add_operands() {
        // An add summing the same edge twice (e.g. an identity skip plus a
        // long skip that resolves to the immediately preceding segment)
        // must be rejected statically — the stream planner keys FIFOs by
        // (edge, consumer), so duplicates would stall at runtime.
        let mut g = tiny();
        let conv = g.find("conv").unwrap();
        let relu = g.find("relu").unwrap();
        let add = g.add_simple(
            "add",
            Op::Add { out_exp: -5 },
            &[Edge::new(relu, 0), Edge::new(conv, 0), Edge::new(conv, 0)],
        );
        let err = g.validate().unwrap_err();
        assert!(err.contains("duplicate input edge"), "{err}");
        assert!(err.contains("conv"), "names the duplicated producer: {err}");
        // De-duplicated, the same merge is fine.
        g.node_mut(add).inputs.truncate(2);
        assert!(g.validate().is_ok());
    }
}
