//! QONNX-style graph interchange (paper Fig. 2: the flow consumes the
//! quantized network as a QONNX graph — "an easy-to-parse description of
//! the network, including information such as layer type, input and output
//! quantization, and layer connections").
//!
//! We serialize the IR to a QONNX-flavored JSON document: a `graph` with
//! `nodes` (op_type, name, inputs, attributes) — structurally the ONNX
//! protobuf schema rendered as JSON, restricted to the ops this flow
//! supports.  `import` accepts both our exports and hand-written files;
//! exponents ride in `quant` attributes the way QONNX carries its
//! Quant-node metadata.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::Json;

use super::ir::{BatchNormAttrs, ConvAttrs, Edge, Graph, InputRole, MergedDownsample, Op};

/// Serialize a graph to QONNX-flavored JSON.
pub fn export(g: &Graph) -> Json {
    let mut nodes = Vec::new();
    for n in g.live() {
        let mut node = BTreeMap::new();
        node.insert("name".into(), Json::Str(n.name.clone()));
        node.insert("op_type".into(), Json::Str(op_type(&n.op).into()));
        let inputs: Vec<Json> = n
            .inputs
            .iter()
            .map(|(e, r)| {
                let mut o = BTreeMap::new();
                o.insert("node".into(), Json::Str(g.node(e.node).name.clone()));
                o.insert("port".into(), Json::Int(e.port as i64));
                if *r == InputRole::SkipInit {
                    o.insert("role".into(), Json::Str("skip_init".into()));
                }
                Json::Object(o)
            })
            .collect();
        node.insert("inputs".into(), Json::Array(inputs));
        node.insert("attributes".into(), attributes(&n.op));
        nodes.push(Json::Object(node));
    }
    let mut graph = BTreeMap::new();
    graph.insert("nodes".into(), Json::Array(nodes));
    let mut doc = BTreeMap::new();
    doc.insert("format".into(), Json::Str("qonnx-json".into()));
    doc.insert("ir_version".into(), Json::Int(1));
    doc.insert("graph".into(), Json::Object(graph));
    Json::Object(doc)
}

fn op_type(op: &Op) -> &'static str {
    match op {
        Op::Input { .. } => "Input",
        Op::Conv(_) => "QConv",
        Op::BatchNorm(_) => "BatchNormalization",
        Op::Relu => "Relu",
        Op::Add { .. } => "Add",
        Op::MaxPool { .. } => "MaxPool",
        Op::GlobalAvgPool { .. } => "GlobalAveragePool",
        Op::Linear { .. } => "QGemm",
    }
}

fn attributes(op: &Op) -> Json {
    let mut a = BTreeMap::new();
    let mut put = |k: &str, v: i64| {
        a.insert(k.to_string(), Json::Int(v));
    };
    match op {
        Op::Input { h, w, c, exp } => {
            put("height", *h as i64);
            put("width", *w as i64);
            put("channels", *c as i64);
            put("quant_exp", *exp as i64);
        }
        Op::Conv(c) => {
            put("cin", c.cin as i64);
            put("cout", c.cout as i64);
            put("kernel", c.k as i64);
            put("stride", c.stride as i64);
            put("pad", c.pad as i64);
            put("relu", c.relu as i64);
            put("weight_exp", c.w_exp as i64);
            put("out_exp", c.out_exp as i64);
            put("forwards_input", c.forwards_input as i64);
            put("raw_output", c.raw_output as i64);
            if let Some(m) = &c.merged_downsample {
                a.insert(
                    "merged_downsample".into(),
                    Json::Object(BTreeMap::from([
                        ("name".to_string(), Json::Str(m.name.clone())),
                        ("cout".to_string(), Json::Int(m.cout as i64)),
                        ("kernel".to_string(), Json::Int(m.k as i64)),
                        ("stride".to_string(), Json::Int(m.stride as i64)),
                        ("pad".to_string(), Json::Int(m.pad as i64)),
                        ("weight_exp".to_string(), Json::Int(m.w_exp as i64)),
                        ("out_exp".to_string(), Json::Int(m.out_exp as i64)),
                    ])),
                );
            }
        }
        Op::BatchNorm(b) => {
            put("channels", b.channels as i64);
            a.insert(
                "scale".into(),
                Json::Array(b.scale.iter().map(|&v| Json::Float(v as f64)).collect()),
            );
            a.insert(
                "shift".into(),
                Json::Array(b.shift.iter().map(|&v| Json::Float(v as f64)).collect()),
            );
        }
        Op::Relu => {}
        Op::Add { out_exp } => put("out_exp", *out_exp as i64),
        Op::MaxPool { k, stride } => {
            put("kernel", *k as i64);
            put("stride", *stride as i64);
        }
        Op::GlobalAvgPool { out_exp } => put("out_exp", *out_exp as i64),
        Op::Linear { cin, cout, w_exp } => {
            put("cin", *cin as i64);
            put("cout", *cout as i64);
            put("weight_exp", *w_exp as i64);
        }
    }
    Json::Object(a)
}

/// Look up an integer attribute; `Ok(None)` if absent, typed error if
/// present with a non-integer value (silently reading garbage as 0 is
/// how untrusted files used to reach shape inference and abort there).
fn opt_int(attrs: &Json, node: &str, k: &str) -> Result<Option<i64>> {
    match attrs.get(k) {
        None => Ok(None),
        Some(j) => j
            .as_i64()
            .map(Some)
            .ok_or_else(|| anyhow!("{node}.{k}: expected an integer attribute")),
    }
}

/// Required dimension: present, integral, and at least `min` (stride 0
/// or channels 0 would divide-by-zero / degenerate downstream).
fn dim(attrs: &Json, node: &str, k: &str, min: usize) -> Result<usize> {
    let v = opt_int(attrs, node, k)?
        .ok_or_else(|| anyhow!("{node}.{k}: missing required attribute"))?;
    let u = usize::try_from(v).map_err(|_| anyhow!("{node}.{k}: negative value {v}"))?;
    if u < min {
        bail!("{node}.{k}: value {u} below minimum {min}");
    }
    Ok(u)
}

/// Optional quantization exponent / flag-style integer, defaulting to
/// `def` when absent (hand-written files may omit flags), typed error on
/// a non-integer or out-of-range value.
fn exp_or(attrs: &Json, node: &str, k: &str, def: i32) -> Result<i32> {
    match opt_int(attrs, node, k)? {
        None => Ok(def),
        Some(v) => {
            i32::try_from(v).map_err(|_| anyhow!("{node}.{k}: exponent {v} out of i32 range"))
        }
    }
}

fn flag(attrs: &Json, node: &str, k: &str) -> Result<bool> {
    Ok(opt_int(attrs, node, k)?.unwrap_or(0) != 0)
}

/// Parse a QONNX-flavored JSON document back into a graph.
///
/// Never panics on malformed input: every missing/ill-typed/out-of-range
/// field is a typed `Err` naming the node and attribute (regression
/// corpus in this module's tests and in `tests/verify_analysis.rs`).
pub fn import(doc: &Json) -> Result<Graph> {
    let nodes = doc
        .at("graph/nodes")
        .and_then(|j| j.as_array())
        .ok_or_else(|| anyhow!("missing graph/nodes"))?;
    let mut g = Graph::new();
    let mut by_name: BTreeMap<String, usize> = BTreeMap::new();
    for n in nodes {
        let name = n
            .get("name")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow!("node missing name"))?
            .to_string();
        if by_name.contains_key(&name) {
            // A silent overwrite would rebind every earlier edge that
            // names this node to the later definition.
            bail!("duplicate node name {name}");
        }
        let op_type = n
            .get("op_type")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow!("{name}: missing op_type"))?;
        let attrs = n.get("attributes").cloned().unwrap_or(Json::Object(BTreeMap::new()));
        let a = &attrs;
        // Inputs are resolved before the op so any op-level rejection can
        // name the edges feeding the offending node — in a 50-node file,
        // "unsupported op_type X" without its wiring is undebuggable.
        let mut inputs = Vec::new();
        if let Some(arr) = n.get("inputs").and_then(|j| j.as_array()) {
            for i in arr {
                let src = i
                    .get("node")
                    .and_then(|j| j.as_str())
                    .ok_or_else(|| anyhow!("{name}: input missing source node"))?;
                let port_raw = opt_int(i, &name, "port")?.unwrap_or(0);
                let port = u8::try_from(port_raw)
                    .map_err(|_| anyhow!("{name}: input port {port_raw} out of range"))?;
                let role = match i.get("role").and_then(|j| j.as_str()) {
                    Some("skip_init") => InputRole::SkipInit,
                    _ => InputRole::Data,
                };
                let src_id = *by_name
                    .get(src)
                    .ok_or_else(|| anyhow!("{name}: unknown input node {src}"))?;
                inputs.push((Edge::new(src_id, port), role));
            }
        }
        let op = match op_type {
            "Input" => Op::Input {
                h: dim(a, &name, "height", 1)?,
                w: dim(a, &name, "width", 1)?,
                c: dim(a, &name, "channels", 1)?,
                exp: exp_or(a, &name, "quant_exp", 0)?,
            },
            "QConv" => Op::Conv(ConvAttrs {
                cin: dim(a, &name, "cin", 1)?,
                cout: dim(a, &name, "cout", 1)?,
                k: dim(a, &name, "kernel", 1)?,
                stride: dim(a, &name, "stride", 1)?,
                pad: dim(a, &name, "pad", 0)?,
                relu: flag(a, &name, "relu")?,
                w_exp: exp_or(a, &name, "weight_exp", 0)?,
                out_exp: exp_or(a, &name, "out_exp", 0)?,
                forwards_input: flag(a, &name, "forwards_input")?,
                raw_output: flag(a, &name, "raw_output")?,
                merged_downsample: match attrs.get("merged_downsample") {
                    None => None,
                    Some(m) => Some(MergedDownsample {
                        name: m
                            .get("name")
                            .and_then(|j| j.as_str())
                            .ok_or_else(|| anyhow!("{name}.merged_downsample: missing name"))?
                            .into(),
                        cout: dim(m, &name, "cout", 1)?,
                        k: dim(m, &name, "kernel", 1)?,
                        stride: dim(m, &name, "stride", 1)?,
                        pad: dim(m, &name, "pad", 0)?,
                        w_exp: exp_or(m, &name, "weight_exp", 0)?,
                        out_exp: exp_or(m, &name, "out_exp", 0)?,
                    }),
                },
            }),
            "BatchNormalization" => {
                let getv = |k: &str| -> Vec<f32> {
                    attrs
                        .get(k)
                        .and_then(|j| j.as_array())
                        .map(|arr| {
                            arr.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect()
                        })
                        .unwrap_or_default()
                };
                Op::BatchNorm(BatchNormAttrs {
                    channels: dim(a, &name, "channels", 1)?,
                    scale: getv("scale"),
                    shift: getv("shift"),
                })
            }
            "Relu" => Op::Relu,
            "Add" => Op::Add { out_exp: exp_or(a, &name, "out_exp", 0)? },
            "MaxPool" => Op::MaxPool {
                k: dim(a, &name, "kernel", 1)?,
                stride: dim(a, &name, "stride", 1)?,
            },
            "GlobalAveragePool" => Op::GlobalAvgPool { out_exp: exp_or(a, &name, "out_exp", 0)? },
            "QGemm" => Op::Linear {
                cin: dim(a, &name, "cin", 1)?,
                cout: dim(a, &name, "cout", 1)?,
                w_exp: exp_or(a, &name, "weight_exp", 0)?,
            },
            other => bail!(
                "{name}: unsupported op_type {other} (input edges: [{}])",
                edge_list(&g, &inputs)
            ),
        };
        let id = g.add(name.clone(), op, inputs);
        by_name.insert(name, id);
    }
    // Structural rejection (arity, ports, topology) also names the
    // failing node's input edges, not just the node.
    g.validate().map_err(|e| {
        let ctx = g
            .live()
            .find(|n| e.contains(&format!("node {}", n.name)))
            .map(|n| format!(" (node {} input edges: [{}])", n.name, edge_list(&g, &n.inputs)))
            .unwrap_or_default();
        anyhow!("{e}{ctx}")
    })?;
    Ok(g)
}

/// `producer.port` list of a node's input edges, for error context.
fn edge_list(g: &Graph, inputs: &[(Edge, InputRole)]) -> String {
    inputs
        .iter()
        .map(|(e, _)| format!("{}.{}", g.node(e.node).name, e.port))
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::models::{
        build_optimized_graph, build_unoptimized_graph, default_exps, longskipnet, resnet20,
        resnet8, skipnet, tiednet,
    };
    use crate::passes::equivalent;

    #[test]
    fn roundtrip_both_forms_both_archs() {
        for arch in [resnet8(), resnet20(), skipnet(), longskipnet(), tiednet(3)] {
            let (act, w) = default_exps(&arch);
            for g in [
                build_unoptimized_graph(&arch, &act, &w),
                build_optimized_graph(&arch, &act, &w),
            ] {
                let doc = export(&g);
                let text = doc.to_string();
                let parsed = Json::parse(&text).unwrap();
                let g2 = import(&parsed).unwrap();
                assert!(equivalent(&g, &g2), "{} roundtrip", arch.name);
            }
        }
    }

    #[test]
    fn import_rejects_unknown_ops() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[{"name":"x","op_type":"Softmax","inputs":[],"attributes":{}}]}}"#,
        )
        .unwrap();
        assert!(import(&doc).is_err());
    }

    /// Rejections carry wiring context: the failing node AND the edges
    /// feeding it, for both unsupported ops and structural violations.
    #[test]
    fn rejections_name_the_node_and_its_input_edges() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[
                {"name":"a","op_type":"Relu","inputs":[],"attributes":{}},
                {"name":"b","op_type":"Relu","inputs":[],"attributes":{}},
                {"name":"sm","op_type":"Softmax",
                 "inputs":[{"node":"a","port":0},{"node":"b","port":0}],
                 "attributes":{}}]}}"#,
        )
        .unwrap();
        let msg = format!("{:#}", import(&doc).unwrap_err());
        assert!(msg.contains("sm"), "{msg}");
        assert!(msg.contains("unsupported op_type Softmax"), "{msg}");
        assert!(msg.contains("a.0") && msg.contains("b.0"), "names the input edges: {msg}");

        // Topology violation (an Add needs >= 2 operands): the validate
        // error is enriched with the add's actual input edges.
        let doc = Json::parse(
            r#"{"graph":{"nodes":[
                {"name":"in","op_type":"Input","inputs":[],
                 "attributes":{"height":4,"width":4,"channels":2,"quant_exp":-7}},
                {"name":"add","op_type":"Add",
                 "inputs":[{"node":"in","port":0}],"attributes":{"out_exp":-5}}]}}"#,
        )
        .unwrap();
        let msg = format!("{:#}", import(&doc).unwrap_err());
        assert!(msg.contains("add"), "{msg}");
        assert!(msg.contains("in.0"), "names the offending input edge: {msg}");
    }

    /// Multi-input merges (long skips converging on one Add) import as
    /// first-class topology — arity is bounded only by validate's >= 2.
    #[test]
    fn imports_multi_input_adds() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[
                {"name":"in","op_type":"Input","inputs":[],
                 "attributes":{"height":4,"width":4,"channels":2,"quant_exp":-7}},
                {"name":"r1","op_type":"Relu","inputs":[{"node":"in","port":0}],"attributes":{}},
                {"name":"r2","op_type":"Relu","inputs":[{"node":"in","port":0}],"attributes":{}},
                {"name":"add","op_type":"Add","attributes":{"out_exp":-5},
                 "inputs":[{"node":"r1","port":0},{"node":"r2","port":0},{"node":"in","port":0}]}
                ]}}"#,
        )
        .unwrap();
        let g = import(&doc).unwrap();
        let add = g.find("add").unwrap();
        assert_eq!(g.node(add).inputs.len(), 3);
    }

    #[test]
    fn import_rejects_dangling_edges() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[{"name":"r","op_type":"Relu",
                "inputs":[{"node":"ghost","port":0}],"attributes":{}}]}}"#,
        )
        .unwrap();
        assert!(import(&doc).is_err());
    }

    /// Malformed-input corpus: every entry must come back as a typed
    /// `Err`, never a panic/abort (the `repro verify --qonnx` path runs
    /// on untrusted files).
    #[test]
    fn malformed_corpus_yields_typed_errors() {
        let corpus: &[(&str, &str)] = &[
            ("empty object", r#"{}"#),
            ("nodes not an array", r#"{"graph":{"nodes":42}}"#),
            ("node without a name", r#"{"graph":{"nodes":[{"op_type":"Relu"}]}}"#),
            ("node without op_type", r#"{"graph":{"nodes":[{"name":"x"}]}}"#),
            (
                "conv with zero stride (would divide-by-zero in shapes)",
                r#"{"graph":{"nodes":[
                    {"name":"in","op_type":"Input","inputs":[],
                     "attributes":{"height":8,"width":8,"channels":3,"quant_exp":-7}},
                    {"name":"c","op_type":"QConv","inputs":[{"node":"in","port":0}],
                     "attributes":{"cin":3,"cout":4,"kernel":3,"stride":0,"pad":1,
                       "relu":1,"weight_exp":-9,"out_exp":-7,
                       "forwards_input":0,"raw_output":0}}]}}"#,
            ),
            (
                "conv with negative cin (used to wrap to a huge usize)",
                r#"{"graph":{"nodes":[
                    {"name":"c","op_type":"QConv","inputs":[],
                     "attributes":{"cin":-3,"cout":4,"kernel":3,"stride":1,"pad":1,
                       "relu":1,"weight_exp":-9,"out_exp":-7,
                       "forwards_input":0,"raw_output":0}}]}}"#,
            ),
            (
                "conv missing its kernel attribute",
                r#"{"graph":{"nodes":[
                    {"name":"c","op_type":"QConv","inputs":[],
                     "attributes":{"cin":3,"cout":4,"stride":1,"pad":1}}]}}"#,
            ),
            (
                "string where an integer attribute belongs",
                r#"{"graph":{"nodes":[
                    {"name":"in","op_type":"Input","inputs":[],
                     "attributes":{"height":"tall","width":8,"channels":3}}]}}"#,
            ),
            (
                "input port out of u8 range (used to wrap silently)",
                r#"{"graph":{"nodes":[
                    {"name":"a","op_type":"Relu","inputs":[],"attributes":{}},
                    {"name":"b","op_type":"Relu",
                     "inputs":[{"node":"a","port":300}],"attributes":{}}]}}"#,
            ),
            (
                "duplicate node names (used to rebind earlier edges)",
                r#"{"graph":{"nodes":[
                    {"name":"x","op_type":"Relu","inputs":[],"attributes":{}},
                    {"name":"x","op_type":"Relu","inputs":[],"attributes":{}}]}}"#,
            ),
        ];
        for (what, text) in corpus {
            let doc = Json::parse(text).unwrap_or_else(|e| panic!("{what}: corpus JSON: {e}"));
            assert!(import(&doc).is_err(), "{what}: import must reject this");
        }
    }

    /// Truncating a real export anywhere must fail parsing or import
    /// with a typed error — never abort.  (Truncation can land inside a
    /// string, a number, or between nodes; all must be survivable.)
    #[test]
    fn truncated_exports_never_panic() {
        let (act, w) = default_exps(&resnet8());
        let text = export(&build_optimized_graph(&resnet8(), &act, &w)).to_string();
        let steps = (text.len() / 97).max(1);
        for cut in (0..text.len()).step_by(steps) {
            let prefix = &text[..cut];
            if let Ok(doc) = Json::parse(prefix) {
                // A prefix that happens to parse must still be rejected
                // (or accepted) without panicking.
                let _ = import(&doc);
            }
        }
    }
}
