//! QONNX-style graph interchange (paper Fig. 2: the flow consumes the
//! quantized network as a QONNX graph — "an easy-to-parse description of
//! the network, including information such as layer type, input and output
//! quantization, and layer connections").
//!
//! We serialize the IR to a QONNX-flavored JSON document: a `graph` with
//! `nodes` (op_type, name, inputs, attributes) — structurally the ONNX
//! protobuf schema rendered as JSON, restricted to the ops this flow
//! supports.  `import` accepts both our exports and hand-written files;
//! exponents ride in `quant` attributes the way QONNX carries its
//! Quant-node metadata.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::Json;

use super::ir::{BatchNormAttrs, ConvAttrs, Edge, Graph, InputRole, MergedDownsample, Op};

/// Serialize a graph to QONNX-flavored JSON.
pub fn export(g: &Graph) -> Json {
    let mut nodes = Vec::new();
    for n in g.live() {
        let mut node = BTreeMap::new();
        node.insert("name".into(), Json::Str(n.name.clone()));
        node.insert("op_type".into(), Json::Str(op_type(&n.op).into()));
        let inputs: Vec<Json> = n
            .inputs
            .iter()
            .map(|(e, r)| {
                let mut o = BTreeMap::new();
                o.insert("node".into(), Json::Str(g.node(e.node).name.clone()));
                o.insert("port".into(), Json::Int(e.port as i64));
                if *r == InputRole::SkipInit {
                    o.insert("role".into(), Json::Str("skip_init".into()));
                }
                Json::Object(o)
            })
            .collect();
        node.insert("inputs".into(), Json::Array(inputs));
        node.insert("attributes".into(), attributes(&n.op));
        nodes.push(Json::Object(node));
    }
    let mut graph = BTreeMap::new();
    graph.insert("nodes".into(), Json::Array(nodes));
    let mut doc = BTreeMap::new();
    doc.insert("format".into(), Json::Str("qonnx-json".into()));
    doc.insert("ir_version".into(), Json::Int(1));
    doc.insert("graph".into(), Json::Object(graph));
    Json::Object(doc)
}

fn op_type(op: &Op) -> &'static str {
    match op {
        Op::Input { .. } => "Input",
        Op::Conv(_) => "QConv",
        Op::BatchNorm(_) => "BatchNormalization",
        Op::Relu => "Relu",
        Op::Add { .. } => "Add",
        Op::MaxPool { .. } => "MaxPool",
        Op::GlobalAvgPool { .. } => "GlobalAveragePool",
        Op::Linear { .. } => "QGemm",
    }
}

fn attributes(op: &Op) -> Json {
    let mut a = BTreeMap::new();
    let mut put = |k: &str, v: i64| {
        a.insert(k.to_string(), Json::Int(v));
    };
    match op {
        Op::Input { h, w, c, exp } => {
            put("height", *h as i64);
            put("width", *w as i64);
            put("channels", *c as i64);
            put("quant_exp", *exp as i64);
        }
        Op::Conv(c) => {
            put("cin", c.cin as i64);
            put("cout", c.cout as i64);
            put("kernel", c.k as i64);
            put("stride", c.stride as i64);
            put("pad", c.pad as i64);
            put("relu", c.relu as i64);
            put("weight_exp", c.w_exp as i64);
            put("out_exp", c.out_exp as i64);
            put("forwards_input", c.forwards_input as i64);
            put("raw_output", c.raw_output as i64);
            if let Some(m) = &c.merged_downsample {
                a.insert(
                    "merged_downsample".into(),
                    Json::Object(BTreeMap::from([
                        ("name".to_string(), Json::Str(m.name.clone())),
                        ("cout".to_string(), Json::Int(m.cout as i64)),
                        ("kernel".to_string(), Json::Int(m.k as i64)),
                        ("stride".to_string(), Json::Int(m.stride as i64)),
                        ("pad".to_string(), Json::Int(m.pad as i64)),
                        ("weight_exp".to_string(), Json::Int(m.w_exp as i64)),
                        ("out_exp".to_string(), Json::Int(m.out_exp as i64)),
                    ])),
                );
            }
        }
        Op::BatchNorm(b) => {
            put("channels", b.channels as i64);
            a.insert(
                "scale".into(),
                Json::Array(b.scale.iter().map(|&v| Json::Float(v as f64)).collect()),
            );
            a.insert(
                "shift".into(),
                Json::Array(b.shift.iter().map(|&v| Json::Float(v as f64)).collect()),
            );
        }
        Op::Relu => {}
        Op::Add { out_exp } => put("out_exp", *out_exp as i64),
        Op::MaxPool { k, stride } => {
            put("kernel", *k as i64);
            put("stride", *stride as i64);
        }
        Op::GlobalAvgPool { out_exp } => put("out_exp", *out_exp as i64),
        Op::Linear { cin, cout, w_exp } => {
            put("cin", *cin as i64);
            put("cout", *cout as i64);
            put("weight_exp", *w_exp as i64);
        }
    }
    Json::Object(a)
}

/// Parse a QONNX-flavored JSON document back into a graph.
pub fn import(doc: &Json) -> Result<Graph> {
    let nodes = doc
        .at("graph/nodes")
        .and_then(|j| j.as_array())
        .ok_or_else(|| anyhow!("missing graph/nodes"))?;
    let mut g = Graph::new();
    let mut by_name: BTreeMap<String, usize> = BTreeMap::new();
    for n in nodes {
        let name = n
            .get("name")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow!("node missing name"))?
            .to_string();
        let op_type = n.get("op_type").and_then(|j| j.as_str()).unwrap_or_default();
        let attrs = n.get("attributes").cloned().unwrap_or(Json::Object(BTreeMap::new()));
        let geti = |k: &str| -> i64 { attrs.get(k).and_then(|j| j.as_i64()).unwrap_or(0) };
        let op = match op_type {
            "Input" => Op::Input {
                h: geti("height") as usize,
                w: geti("width") as usize,
                c: geti("channels") as usize,
                exp: geti("quant_exp") as i32,
            },
            "QConv" => Op::Conv(ConvAttrs {
                cin: geti("cin") as usize,
                cout: geti("cout") as usize,
                k: geti("kernel") as usize,
                stride: geti("stride") as usize,
                pad: geti("pad") as usize,
                relu: geti("relu") != 0,
                w_exp: geti("weight_exp") as i32,
                out_exp: geti("out_exp") as i32,
                forwards_input: geti("forwards_input") != 0,
                raw_output: geti("raw_output") != 0,
                merged_downsample: attrs.get("merged_downsample").map(|m| {
                    let gi = |k: &str| m.get(k).and_then(|j| j.as_i64()).unwrap_or(0);
                    MergedDownsample {
                        name: m.get("name").and_then(|j| j.as_str()).unwrap_or_default().into(),
                        cout: gi("cout") as usize,
                        k: gi("kernel") as usize,
                        stride: gi("stride") as usize,
                        pad: gi("pad") as usize,
                        w_exp: gi("weight_exp") as i32,
                        out_exp: gi("out_exp") as i32,
                    }
                }),
            }),
            "BatchNormalization" => {
                let getv = |k: &str| -> Vec<f32> {
                    attrs
                        .get(k)
                        .and_then(|j| j.as_array())
                        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
                        .unwrap_or_default()
                };
                Op::BatchNorm(BatchNormAttrs {
                    channels: geti("channels") as usize,
                    scale: getv("scale"),
                    shift: getv("shift"),
                })
            }
            "Relu" => Op::Relu,
            "Add" => Op::Add { out_exp: geti("out_exp") as i32 },
            "MaxPool" => Op::MaxPool { k: geti("kernel") as usize, stride: geti("stride") as usize },
            "GlobalAveragePool" => Op::GlobalAvgPool { out_exp: geti("out_exp") as i32 },
            "QGemm" => Op::Linear {
                cin: geti("cin") as usize,
                cout: geti("cout") as usize,
                w_exp: geti("weight_exp") as i32,
            },
            other => bail!("unsupported op_type {other}"),
        };
        let mut inputs = Vec::new();
        if let Some(arr) = n.get("inputs").and_then(|j| j.as_array()) {
            for i in arr {
                let src = i.get("node").and_then(|j| j.as_str()).unwrap_or_default();
                let port = i.get("port").and_then(|j| j.as_i64()).unwrap_or(0) as u8;
                let role = match i.get("role").and_then(|j| j.as_str()) {
                    Some("skip_init") => InputRole::SkipInit,
                    _ => InputRole::Data,
                };
                let src_id = *by_name
                    .get(src)
                    .ok_or_else(|| anyhow!("{name}: unknown input node {src}"))?;
                inputs.push((Edge::new(src_id, port), role));
            }
        }
        let id = g.add(name.clone(), op, inputs);
        by_name.insert(name, id);
    }
    g.validate().map_err(|e| anyhow!("{e}"))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{
        build_optimized_graph, build_unoptimized_graph, default_exps, resnet20, resnet8,
    };
    use crate::passes::equivalent;

    #[test]
    fn roundtrip_both_forms_both_archs() {
        for arch in [resnet8(), resnet20()] {
            let (act, w) = default_exps(&arch);
            for g in [
                build_unoptimized_graph(&arch, &act, &w),
                build_optimized_graph(&arch, &act, &w),
            ] {
                let doc = export(&g);
                let text = doc.to_string();
                let parsed = Json::parse(&text).unwrap();
                let g2 = import(&parsed).unwrap();
                assert!(equivalent(&g, &g2), "{} roundtrip", arch.name);
            }
        }
    }

    #[test]
    fn import_rejects_unknown_ops() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[{"name":"x","op_type":"Softmax","inputs":[],"attributes":{}}]}}"#,
        )
        .unwrap();
        assert!(import(&doc).is_err());
    }

    #[test]
    fn import_rejects_dangling_edges() {
        let doc = Json::parse(
            r#"{"graph":{"nodes":[{"name":"r","op_type":"Relu",
                "inputs":[{"node":"ghost","port":0}],"attributes":{}}]}}"#,
        )
        .unwrap();
        assert!(import(&doc).is_err());
    }
}
