//! PJRT runtime: load the AOT artifacts and execute them.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only consumer of its outputs.  HLO *text* is the interchange format —
//! the crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids (see
//! /opt/xla-example/README.md).

mod artifacts;
mod engine;

pub use artifacts::{Artifacts, ModelVariant, ProbeSet};
pub use engine::{Engine, LoadedModel};
