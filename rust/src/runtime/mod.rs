//! Execution runtime: the backend-agnostic inference API and its
//! substrates.
//!
//! [`backend`] defines the [`InferenceBackend`] / [`BackendFactory`]
//! traits that the coordinator serves through; this module also hosts the
//! PJRT substrate ([`Engine`] / [`PjrtBackend`]), which loads the AOT
//! artifacts and executes them.
//!
//! Python runs once at build time (`make artifacts`); the PJRT engine is
//! the only consumer of its outputs.  HLO *text* is the interchange
//! format — the crate's xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos (64-bit instruction ids), while the text parser reassigns ids
//! (see /opt/xla-example/README.md).  The golden and sim backends have no
//! artifact dependency at all.

#![deny(clippy::disallowed_methods)]

mod artifacts;
pub mod backend;
mod engine;

pub use artifacts::{Artifacts, ModelVariant, ProbeSet};
pub use backend::{
    infer_tiled, BackendFactory, GoldenBackend, GoldenFactory, InferenceBackend, PjrtFactory,
    SimBackend, SimFactory, StreamBackend, StreamFactory,
};
pub use engine::{Engine, LoadedModel, PjrtBackend};
