//! The backend-agnostic inference API.
//!
//! The paper's accelerator is a free-running dataflow engine: the host
//! never cares *what* executes a batch, only that frames go in and logits
//! come out.  This module makes that boundary explicit: everything above
//! it (the coordinator's router, batcher and metrics) talks to a
//! [`InferenceBackend`] and can therefore run against any of four
//! substrates:
//!
//! * [`PjrtBackend`](super::PjrtBackend) — the AOT-compiled HLO executed
//!   on PJRT (real numerics, needs `make artifacts`);
//! * [`GoldenBackend`] — the in-process integer golden model (exact
//!   int8/int32 numerics, artifact-free);
//! * [`SimBackend`] — golden numerics paced by the cycle-approximate
//!   dataflow simulator (realistic accelerator timing for load tests);
//! * [`StreamBackend`] — the same exact numerics executed by a
//!   persistent streaming pipeline pool ([`crate::stream`]): stage
//!   threads spawned once and kept alive across frames, `replicas`
//!   pipeline copies behind one work queue, ILP-driven FIFO depths and
//!   `och_par` channel workers, measured peak buffering.
//!
//! Backends are constructed through a [`BackendFactory`] *inside* the
//! executor thread that will use them — PJRT executables are not `Send`,
//! so they must never cross a thread boundary.  The factory itself is
//! plain data (`Send + Sync`) and can be handed to any number of workers.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{Batcher, BatcherConfig};
use crate::graph::Graph;
use crate::hls::{resources::fit_to_board, Board, KV260};
use crate::ilp::loads_from_arch;
use crate::models::{
    arch_by_name, build_optimized_graph, default_exps, synthetic_weights, ModelWeights,
};
use crate::quant::{QTensor, Shape4};
use crate::sim::{build_network, golden, SimOptions};
use crate::stream::{ElasticConfig, StreamConfig, StreamPool, StreamStats, WorkerBudget};

/// Something that can run inference batches for one architecture.
///
/// The contract: `infer_batch` is called with inputs whose batch size is
/// one of `buckets()` (backends may accept other sizes, but callers only
/// rely on the buckets).  The result is the logits tensor `(N, 1, 1, C)`
/// at exponent 0, row `i` corresponding to input frame `i`.
pub trait InferenceBackend {
    /// Architecture name this backend serves (e.g. `"resnet8"`).
    fn arch(&self) -> &str;
    /// Batch-size buckets executed natively, ascending.
    fn buckets(&self) -> &[usize];
    /// Execute one bucket-sized batch.
    fn infer_batch(&self, input: &QTensor) -> Result<QTensor>;
    /// Largest bucket this backend *wants* dispatched, or `None` to defer
    /// to the batcher policy's `max_bucket` cap.  Streaming pools return
    /// their in-flight capacity: the derived `[1, capacity]` bucket set
    /// is the whole point of frame-level pipelining, and the policy's
    /// default cap (tuned for PJRT executables) must not strip it.
    fn preferred_max_bucket(&self) -> Option<usize> {
        None
    }
    /// Streaming backends report their pool's buffering gauges here —
    /// `(peak buffered elements, whole-tensor comparison base)`, both
    /// aggregated across pool replicas — so the serving path can export
    /// them cheaply after every batch (no per-buffer name clones; the
    /// full named report stays on `StreamBackend::last_stats`).
    /// Everything else returns `None`.
    fn stream_gauges(&self) -> Option<(u64, u64)> {
        None
    }
    /// Serving-layer load hint: the router reports its per-arch queue
    /// depth — plus the network-ingress admission-queue depth when a
    /// TCP front-end ([`crate::net`]) is running — here on every
    /// claim-loop pass.  Elastic streaming pools fold the hint into
    /// their replica-scaling signal (so the pool can grow *before* its
    /// own queue backs up, even while the backlog is still buffered at
    /// the socket tier); everything else ignores it.  Must be cheap —
    /// it is called under the router's queue lock.
    fn load_hint(&self, _queued: usize) {}
    /// Live pipeline-replica count of a streaming pool backend (exported
    /// to the serving metrics as a gauge).  `None` for backends without
    /// a replica pool.
    fn replica_count(&self) -> Option<usize> {
        None
    }
    /// Full per-stage stall-attribution report of a streaming pool
    /// backend ([`crate::obs::StallReport`]): busy / blocked-on-push /
    /// blocked-on-pop fractions per stage thread, per-FIFO occupancy
    /// histograms and the derived bottleneck verdict.  Heavier than
    /// [`Self::stream_gauges`] (clones stage and edge rows), so the
    /// serving path throttles how often it asks.  `None` for backends
    /// without a pipeline pool, and before the first served frame.
    fn stall_report(&self) -> Option<crate::obs::StallReport> {
        None
    }
    /// This backend's row in the shared worker budget —
    /// `(held, reserved, denied)` workers — exported to the per-arch
    /// serving metrics as lease gauges.  `None` for backends outside a
    /// [`crate::stream::WorkerBudget`].
    fn budget_gauges(&self) -> Option<(u64, u64, u64)> {
        None
    }
}

/// Constructs [`InferenceBackend`]s inside their executor thread.
///
/// PJRT executables are not `Send`, so the coordinator never moves a
/// backend between threads: it moves a factory (plain data) into each
/// worker and calls `create()` there.  One factory may be shared by many
/// workers of the same pool.
pub trait BackendFactory: Send + Sync {
    /// Architecture the created backends will serve (the router's key).
    fn arch(&self) -> &str;
    /// Build a fresh backend.  Called once per executor thread.
    fn create(&self) -> Result<Box<dyn InferenceBackend>>;
}

/// Run a batch of any size through bucket-sized `infer_batch` calls.
///
/// The decomposition is the coordinator's [`Batcher::plan`] — the single
/// batch-tiling policy in the crate (the serving path and this offline
/// path can no longer drift).  Tail frames are zero-padded into the
/// cheapest covering bucket under the dispatch-overhead cost model.
pub fn infer_tiled(backend: &dyn InferenceBackend, input: &QTensor) -> Result<QTensor> {
    let buckets = backend.buckets().to_vec();
    anyhow::ensure!(!buckets.is_empty(), "no buckets for {}", backend.arch());
    let batcher = Batcher::new(BatcherConfig {
        buckets,
        max_bucket: usize::MAX,
        ..Default::default()
    });
    let n = input.shape.n;
    let (h, w, c) = (input.shape.h, input.shape.w, input.shape.c);
    let frame = h * w * c;
    let mut out_data = Vec::with_capacity(n * 10);
    let mut classes = 10;
    let mut done = 0usize;
    for plan in batcher.plan(n) {
        let mut chunk = vec![0i32; plan.bucket * frame];
        chunk[..plan.take * frame]
            .copy_from_slice(&input.data[done * frame..(done + plan.take) * frame]);
        let q = QTensor::from_vec(Shape4::new(plan.bucket, h, w, c), input.exp, chunk);
        let logits = backend.infer_batch(&q)?;
        classes = logits.shape.c;
        out_data.extend_from_slice(&logits.data[..plan.take * classes]);
        done += plan.take;
    }
    Ok(QTensor::from_vec(Shape4::new(n, 1, 1, classes), 0, out_data))
}

// ------------------------------------------------- model construction

/// Deterministic synthetic weights + the optimized graph for `arch_name`.
fn model_parts_synthetic(arch_name: &str, seed: u64) -> Result<(Graph, ModelWeights)> {
    let arch = arch_by_name(arch_name).ok_or_else(|| anyhow!("unknown arch {arch_name}"))?;
    let weights = synthetic_weights(&arch, seed);
    let graph = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    Ok((graph, weights))
}

/// Trained weights from the artifacts directory + the optimized graph
/// (reads the weight blobs only — no HLO, no PJRT).
fn model_parts_artifacts(dir: &Path, arch_name: &str) -> Result<(Graph, ModelWeights)> {
    let arch = arch_by_name(arch_name).ok_or_else(|| anyhow!("unknown arch {arch_name}"))?;
    let weights = ModelWeights::load(dir, arch_name)?;
    let graph = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    Ok((graph, weights))
}

fn normalize_buckets(buckets: &[usize], what: &str) -> Result<Vec<usize>> {
    let mut buckets = buckets.to_vec();
    buckets.sort_unstable();
    buckets.dedup();
    anyhow::ensure!(!buckets.is_empty(), "{what} backend needs at least one bucket");
    Ok(buckets)
}

// ------------------------------------------------------------- golden

/// Artifact-free backend: the exact int8/int32 golden numerics from
/// [`sim::golden`](crate::sim::golden), bit-equal to the jnp oracle and
/// (through the AOT artifacts) to the PJRT-executed HLO.
///
/// Accepts any batch size, but advertises a configurable bucket set so
/// the batcher exercises the same tiling decisions it would make against
/// real baked-batch executables.
pub struct GoldenBackend {
    arch: String,
    graph: Graph,
    weights: ModelWeights,
    buckets: Vec<usize>,
}

impl GoldenBackend {
    /// Bucket set mirroring the default AOT artifacts (b1/b8/b64).
    pub const DEFAULT_BUCKETS: &'static [usize] = &[1, 8, 64];

    /// Deterministic synthetic weights — runs anywhere, no artifacts.
    pub fn synthetic(arch_name: &str, seed: u64, buckets: &[usize]) -> Result<GoldenBackend> {
        let (graph, weights) = model_parts_synthetic(arch_name, seed)?;
        Self::from_parts(arch_name, graph, weights, buckets)
    }

    /// Real trained weights from the artifacts directory (reads the
    /// weight blobs only — no HLO, no PJRT).
    pub fn from_artifacts(dir: &Path, arch_name: &str, buckets: &[usize]) -> Result<GoldenBackend> {
        let (graph, weights) = model_parts_artifacts(dir, arch_name)?;
        Self::from_parts(arch_name, graph, weights, buckets)
    }

    fn from_parts(
        arch: &str,
        graph: Graph,
        weights: ModelWeights,
        buckets: &[usize],
    ) -> Result<GoldenBackend> {
        let buckets = normalize_buckets(buckets, "golden")?;
        Ok(GoldenBackend { arch: arch.to_string(), graph, weights, buckets })
    }
}

impl InferenceBackend for GoldenBackend {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        golden::run(&self.graph, &self.weights, input)
    }
}

/// Factory for [`GoldenBackend`]s.
pub struct GoldenFactory {
    arch: String,
    seed: u64,
    buckets: Vec<usize>,
    /// `Some(dir)` — load trained weights from the artifacts directory;
    /// `None` — deterministic synthetic weights.
    artifacts: Option<PathBuf>,
}

impl GoldenFactory {
    /// Synthetic weights: runs anywhere.
    pub fn synthetic(arch: &str, seed: u64) -> GoldenFactory {
        GoldenFactory {
            arch: arch.to_string(),
            seed,
            buckets: GoldenBackend::DEFAULT_BUCKETS.to_vec(),
            artifacts: None,
        }
    }

    /// Trained weights from the artifacts directory.
    pub fn from_artifacts(dir: PathBuf, arch: &str) -> GoldenFactory {
        GoldenFactory {
            arch: arch.to_string(),
            seed: 0,
            buckets: GoldenBackend::DEFAULT_BUCKETS.to_vec(),
            artifacts: Some(dir),
        }
    }

    /// Trained weights when the artifacts manifest is present, else the
    /// `seed`-deterministic synthetic fallback (fully artifact-free).
    pub fn auto(dir: PathBuf, arch: &str, seed: u64) -> GoldenFactory {
        if dir.join("manifest.json").exists() {
            Self::from_artifacts(dir, arch)
        } else {
            Self::synthetic(arch, seed)
        }
    }

    /// Override the advertised bucket set.
    pub fn with_buckets(mut self, buckets: &[usize]) -> GoldenFactory {
        self.buckets = buckets.to_vec();
        self
    }
}

impl BackendFactory for GoldenFactory {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn create(&self) -> Result<Box<dyn InferenceBackend>> {
        let b = match &self.artifacts {
            Some(dir) => GoldenBackend::from_artifacts(dir, &self.arch, &self.buckets)?,
            None => GoldenBackend::synthetic(&self.arch, self.seed, &self.buckets)?,
        };
        Ok(Box::new(b))
    }
}

// ---------------------------------------------------------------- sim

/// Golden numerics paced by the cycle-approximate dataflow simulator.
///
/// At construction the discrete-event network for the architecture is
/// built (ILP allocation + resource closure on `board`) and run once to
/// calibrate first-frame latency and steady-state initiation interval.
/// Each `infer_batch` then takes *at least* the modeled accelerator time
/// `latency + (n-1) * II` at the board clock — if the golden compute is
/// slower than the modeled fabric (it usually is for large nets), the
/// call is compute-bound and no extra delay is added.  Use it to load-test
/// the router with realistic timing, artifact-free.
pub struct SimBackend {
    inner: GoldenBackend,
    latency: Duration,
    per_frame: Duration,
}

impl SimBackend {
    pub fn synthetic(
        arch_name: &str,
        seed: u64,
        buckets: &[usize],
        board: &Board,
    ) -> Result<SimBackend> {
        let inner = GoldenBackend::synthetic(arch_name, seed, buckets)?;
        let (latency, per_frame) = calibrate(arch_name, board)?;
        Ok(SimBackend::with_timing(inner, latency, per_frame))
    }

    /// Assemble from an already-calibrated timing model (the factory
    /// calibrates once and shares the result across workers).
    fn with_timing(inner: GoldenBackend, latency: Duration, per_frame: Duration) -> SimBackend {
        SimBackend { inner, latency, per_frame }
    }

    /// Modeled (first-frame latency, steady-state per-frame interval).
    pub fn timing(&self) -> (Duration, Duration) {
        (self.latency, self.per_frame)
    }
}

/// Run the process-network simulation once and convert cycles to wall
/// time at the board clock.
fn calibrate(arch_name: &str, board: &Board) -> Result<(Duration, Duration)> {
    let arch = arch_by_name(arch_name).ok_or_else(|| anyhow!("unknown arch {arch_name}"))?;
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let (_, cfg, _) = fit_to_board(&arch.name, &g, &loads, board, 2)?;
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 3, ..Default::default() })?;
    let rep = net.run(3);
    anyhow::ensure!(!rep.deadlocked, "simulated dataflow deadlocked during calibration");
    let cyc = |c: u64| Duration::from_secs_f64(c as f64 / (board.clock_mhz * 1e6));
    Ok((cyc(rep.latency_cycles), cyc(rep.ii_cycles)))
}

impl InferenceBackend for SimBackend {
    fn arch(&self) -> &str {
        self.inner.arch()
    }

    fn buckets(&self) -> &[usize] {
        self.inner.buckets()
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        let t0 = Instant::now();
        let out = self.inner.infer_batch(input)?;
        let modeled =
            self.latency + self.per_frame * input.shape.n.saturating_sub(1) as u32;
        if let Some(pad) = modeled.checked_sub(t0.elapsed()) {
            std::thread::sleep(pad);
        }
        Ok(out)
    }
}

/// Factory for [`SimBackend`]s.
///
/// The deterministic timing calibration (ILP solve + board fit + 3-frame
/// discrete-event simulation) runs once and is shared by every worker the
/// factory serves.
pub struct SimFactory {
    arch: String,
    seed: u64,
    buckets: Vec<usize>,
    board: &'static Board,
    timing: std::sync::Mutex<Option<(Duration, Duration)>>,
}

impl SimFactory {
    /// Synthetic weights on the KV260 timing model.
    pub fn synthetic(arch: &str, seed: u64) -> SimFactory {
        SimFactory {
            arch: arch.to_string(),
            seed,
            buckets: GoldenBackend::DEFAULT_BUCKETS.to_vec(),
            board: &KV260,
            timing: std::sync::Mutex::new(None),
        }
    }

    pub fn with_board(mut self, board: &'static Board) -> SimFactory {
        self.board = board;
        self
    }

    pub fn with_buckets(mut self, buckets: &[usize]) -> SimFactory {
        self.buckets = buckets.to_vec();
        self
    }

    fn timing(&self) -> Result<(Duration, Duration)> {
        let mut cached = self.timing.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(t) = *cached {
            return Ok(t);
        }
        let t = calibrate(&self.arch, self.board)?;
        *cached = Some(t);
        Ok(t)
    }
}

impl BackendFactory for SimFactory {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn create(&self) -> Result<Box<dyn InferenceBackend>> {
        let (latency, per_frame) = self.timing()?;
        let inner = GoldenBackend::synthetic(&self.arch, self.seed, &self.buckets)?;
        Ok(Box::new(SimBackend::with_timing(inner, latency, per_frame)))
    }
}

// -------------------------------------------------------------- stream

/// The streaming backend: exact golden numerics executed by a
/// **persistent** [`StreamPool`] held for the backend's lifetime — the
/// paper's pipelined dataflow ([`crate::stream`]) with stage threads
/// spawned once, `replicas` pipeline copies behind a shared work queue,
/// bounded FIFOs at the board/ILP-configured depths, and per-layer
/// `och_par` channel-parallel workers.
///
/// `infer_batch` enqueues every frame of the batch before awaiting the
/// first result, so frames pipeline through the pool concurrently
/// (frame-level pipelining) and results come back in order.  Bit-exact
/// versus [`GoldenBackend`] (asserted by integration and property
/// tests); the pool's cumulative [`StreamStats`] buffering report is
/// retrievable via [`StreamBackend::last_stats`] and its gauge pair is
/// exported to the router's metrics through
/// [`InferenceBackend::stream_gauges`].
pub struct StreamBackend {
    arch: String,
    pool: StreamPool,
    buckets: Vec<usize>,
}

impl StreamBackend {
    /// Deterministic synthetic weights — runs anywhere, no artifacts.
    pub fn synthetic(arch_name: &str, seed: u64, buckets: &[usize]) -> Result<StreamBackend> {
        Self::synthetic_with(arch_name, seed, buckets, StreamConfig::default())
    }

    /// Synthetic weights with an explicit pool policy (replicas,
    /// naive-add mode, board, worker caps...).
    pub fn synthetic_with(
        arch_name: &str,
        seed: u64,
        buckets: &[usize],
        cfg: StreamConfig,
    ) -> Result<StreamBackend> {
        let (graph, weights) = model_parts_synthetic(arch_name, seed)?;
        Self::from_parts(arch_name, graph, weights, buckets, cfg)
    }

    /// Real trained weights from the artifacts directory.
    pub fn from_artifacts(dir: &Path, arch_name: &str, buckets: &[usize]) -> Result<StreamBackend> {
        Self::from_artifacts_with(dir, arch_name, buckets, StreamConfig::default())
    }

    /// Trained weights with an explicit pool policy.
    pub fn from_artifacts_with(
        dir: &Path,
        arch_name: &str,
        buckets: &[usize],
        cfg: StreamConfig,
    ) -> Result<StreamBackend> {
        let (graph, weights) = model_parts_artifacts(dir, arch_name)?;
        Self::from_parts(arch_name, graph, weights, buckets, cfg)
    }

    /// Launch the pool.  An empty `buckets` slice sizes the bucket set to
    /// the pool's in-flight capacity (`[1, capacity]`), so the batcher
    /// hands the pool exactly as many frames as it can pipeline.
    fn from_parts(
        arch: &str,
        graph: Graph,
        weights: ModelWeights,
        buckets: &[usize],
        cfg: StreamConfig,
    ) -> Result<StreamBackend> {
        let pool = StreamPool::new(arch, &graph, Arc::new(weights), cfg)?;
        let buckets = if buckets.is_empty() {
            let cap = pool.capacity();
            if cap > 1 { vec![1, cap] } else { vec![1] }
        } else {
            normalize_buckets(buckets, "stream")?
        };
        Ok(StreamBackend { arch: arch.to_string(), pool, buckets })
    }

    /// The persistent pipeline pool (shape, live stats, tickets).
    pub fn pool(&self) -> &StreamPool {
        &self.pool
    }

    /// Cumulative buffering report of the pool — `None` until the first
    /// frame has been served.
    pub fn last_stats(&self) -> Option<StreamStats> {
        if self.pool.frames() == 0 { None } else { Some(self.pool.stats()) }
    }
}

impl InferenceBackend for StreamBackend {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        self.pool.infer(input)
    }

    fn preferred_max_bucket(&self) -> Option<usize> {
        self.buckets.last().copied()
    }

    fn stream_gauges(&self) -> Option<(u64, u64)> {
        if self.pool.frames() == 0 {
            return None;
        }
        let (peak, whole) = self.pool.buffered_gauges();
        Some((peak as u64, whole as u64))
    }

    fn load_hint(&self, queued: usize) {
        self.pool.load_hint(queued);
    }

    fn replica_count(&self) -> Option<usize> {
        Some(self.pool.replicas())
    }

    fn stall_report(&self) -> Option<crate::obs::StallReport> {
        if self.pool.frames() == 0 {
            return None;
        }
        Some(self.pool.stall_report())
    }

    fn budget_gauges(&self) -> Option<(u64, u64, u64)> {
        self.pool
            .budget_stat()
            .map(|(held, reserved, denied)| (held as u64, reserved as u64, denied))
    }
}

/// Factory for [`StreamBackend`]s (each router worker gets its own
/// pool; prefer one worker with `with_replicas(B)` over many workers —
/// replicas share one work queue, workers would each spawn a full pool).
pub struct StreamFactory {
    arch: String,
    seed: u64,
    /// Empty = size buckets to the pool's in-flight capacity.
    buckets: Vec<usize>,
    artifacts: Option<PathBuf>,
    cfg: StreamConfig,
}

impl StreamFactory {
    /// Synthetic weights: runs anywhere.
    pub fn synthetic(arch: &str, seed: u64) -> StreamFactory {
        StreamFactory {
            arch: arch.to_string(),
            seed,
            buckets: Vec::new(),
            artifacts: None,
            cfg: StreamConfig::default(),
        }
    }

    /// Trained weights from the artifacts directory.
    pub fn from_artifacts(dir: PathBuf, arch: &str) -> StreamFactory {
        StreamFactory { artifacts: Some(dir), ..Self::synthetic(arch, 0) }
    }

    /// Trained weights when the artifacts manifest is present, else the
    /// `seed`-deterministic synthetic fallback (fully artifact-free).
    pub fn auto(dir: PathBuf, arch: &str, seed: u64) -> StreamFactory {
        if dir.join("manifest.json").exists() {
            Self::from_artifacts(dir, arch)
        } else {
            Self::synthetic(arch, seed)
        }
    }

    /// Override the advertised bucket set (default: sized to the pool's
    /// in-flight capacity).
    pub fn with_buckets(mut self, buckets: &[usize]) -> StreamFactory {
        self.buckets = buckets.to_vec();
        self
    }

    /// Pipeline replicas behind each created backend's work queue
    /// (`serve --backend stream --replicas B`).
    pub fn with_replicas(mut self, replicas: usize) -> StreamFactory {
        self.cfg.replicas = replicas.max(1);
        self
    }

    /// Elastic replica scaling (`serve --backend stream --min-replicas
    /// A --max-replicas B`): each created pool starts at `min` replicas
    /// and its controller grows/drains whole replicas inside
    /// `min..=max` under the queue-depth signal (including the router's
    /// `load_hint`), overriding the fixed `with_replicas` knob.
    pub fn with_elastic(mut self, min: usize, max: usize) -> StreamFactory {
        let min = min.max(1);
        self.cfg.elastic = Some(ElasticConfig {
            min_replicas: min,
            max_replicas: max.max(min),
            ..Default::default()
        });
        self
    }

    /// Output-width unroll for window sizing, group width and column
    /// workers (`serve --backend stream --ow-par N`; 2 = the paper's
    /// DSP-packing default).
    pub fn with_ow_par(mut self, ow_par: usize) -> StreamFactory {
        self.cfg.ow_par = ow_par.max(1);
        self
    }

    /// Window-buffer storage mode (`serve --backend stream
    /// --window-storage rows|slices`; slice-granular by default).
    pub fn with_storage(mut self, storage: crate::stream::WindowStorage) -> StreamFactory {
        self.cfg.window_storage = storage;
        self
    }

    /// Lease replicas from a process-wide worker budget
    /// (`serve`/`listen --worker-budget N`): every pool this factory
    /// creates registers a `min_replicas x stages` reservation against
    /// the shared [`WorkerBudget`] and bids for a lease before each
    /// scale-up — so all arches' pools draw from one thread cap and an
    /// idle arch's headroom serves a bursting one.
    pub fn with_budget(mut self, budget: Arc<WorkerBudget>) -> StreamFactory {
        self.cfg.budget = Some(budget);
        self
    }

    /// Override the whole pool policy for every created backend.
    pub fn with_config(mut self, cfg: StreamConfig) -> StreamFactory {
        self.cfg = cfg;
        self
    }
}

impl BackendFactory for StreamFactory {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn create(&self) -> Result<Box<dyn InferenceBackend>> {
        let b = match &self.artifacts {
            Some(dir) => StreamBackend::from_artifacts_with(
                dir,
                &self.arch,
                &self.buckets,
                self.cfg.clone(),
            )?,
            None => StreamBackend::synthetic_with(
                &self.arch,
                self.seed,
                &self.buckets,
                self.cfg.clone(),
            )?,
        };
        Ok(Box::new(b))
    }
}

// --------------------------------------------------------------- pjrt

/// Factory for [`PjrtBackend`](super::PjrtBackend)s: each worker loads
/// and compiles the arch's HLO variants on its own PJRT client, inside
/// its own thread (the executables are not `Send`).
pub struct PjrtFactory {
    dir: PathBuf,
    arch: String,
}

impl PjrtFactory {
    pub fn new(dir: PathBuf, arch: &str) -> PjrtFactory {
        PjrtFactory { dir, arch: arch.to_string() }
    }
}

impl BackendFactory for PjrtFactory {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn create(&self) -> Result<Box<dyn InferenceBackend>> {
        Ok(Box::new(super::PjrtBackend::load(&self.dir, &self.arch)?))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::data::{synth_batch, TEST_SEED};

    #[test]
    fn golden_backend_matches_direct_golden_run() {
        let backend = GoldenBackend::synthetic("resnet8", 7, &[1, 2, 4]).unwrap();
        let (input, _) = synth_batch(0, 2, TEST_SEED);
        let via_backend = backend.infer_batch(&input).unwrap();
        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let direct = golden::run(&g, &weights, &input).unwrap();
        assert_eq!(via_backend.data, direct.data);
    }

    #[test]
    fn infer_tiled_covers_any_batch_size() {
        let backend = GoldenBackend::synthetic("resnet8", 7, &[1, 2, 4]).unwrap();
        let (input, _) = synth_batch(0, 5, TEST_SEED);
        let tiled = infer_tiled(&backend, &input).unwrap();
        assert_eq!(tiled.shape.n, 5);
        // Tiling (with zero-padded tails) must not change any frame.
        let whole = backend.infer_batch(&input).unwrap();
        assert_eq!(tiled.data, whole.data);
    }

    #[test]
    fn factories_report_their_arch() {
        assert_eq!(GoldenFactory::synthetic("resnet8", 1).arch(), "resnet8");
        assert_eq!(SimFactory::synthetic("resnet20", 1).arch(), "resnet20");
        assert_eq!(StreamFactory::synthetic("resnet8", 1).arch(), "resnet8");
        assert_eq!(PjrtFactory::new(PathBuf::from("/tmp"), "resnet8").arch(), "resnet8");
    }

    #[test]
    fn stream_backend_matches_golden_and_reports_stats() {
        let stream = StreamBackend::synthetic("resnet8", 7, &[1, 2, 4]).unwrap();
        let golden = GoldenBackend::synthetic("resnet8", 7, &[1, 2, 4]).unwrap();
        let (input, _) = synth_batch(0, 2, TEST_SEED);
        assert!(stream.last_stats().is_none());
        let a = stream.infer_batch(&input).unwrap();
        let b = golden.infer_batch(&input).unwrap();
        assert_eq!(a.data, b.data, "stream backend must be bit-exact vs golden");
        let stats = stream.last_stats().expect("stats recorded per batch");
        assert!(stats.peak_buffered_elems() > 0);
        assert!(stats.peak_buffered_elems() < stats.whole_tensor_elems);
    }
}
