//! The PJRT execution engine: compile once, execute many.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::quant::{QTensor, Shape4};

use super::artifacts::{Artifacts, ModelVariant};

/// A compiled model variant ready to execute.
pub struct LoadedModel {
    pub variant: ModelVariant,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute on a full batch.  `input` must match the baked batch size.
    pub fn infer(&self, input: &QTensor) -> Result<QTensor> {
        let b = self.variant.batch;
        anyhow::ensure!(
            input.shape.n == b,
            "batch {} != compiled batch {b}",
            input.shape.n
        );
        let dims: Vec<i64> = self.variant.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&input.data).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<i32>()?;
        let classes = *self.variant.output_shape.last().unwrap_or(&10);
        Ok(QTensor::from_vec(Shape4::new(b, 1, 1, classes), 0, values))
    }
}

/// All compiled variants on one PJRT (CPU) client.
pub struct Engine {
    pub models: BTreeMap<String, LoadedModel>,
    platform: String,
}

impl Engine {
    /// Load and compile every variant in the artifacts directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let artifacts = Artifacts::load(dir)?;
        Self::from_artifacts(&artifacts)
    }

    pub fn from_artifacts(artifacts: &Artifacts) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let platform = client.platform_name();
        let mut models = BTreeMap::new();
        for v in &artifacts.models {
            let proto = xla::HloModuleProto::from_text_file(
                v.hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", v.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", v.name))?;
            models.insert(v.name.clone(), LoadedModel { variant: v.clone(), exe });
        }
        Ok(Engine { models, platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not loaded (have: {:?})", self.models.keys()))
    }

    /// Batch-size buckets available for an arch, ascending.
    pub fn buckets(&self, arch: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .models
            .values()
            .filter(|m| m.variant.arch == arch)
            .map(|m| m.variant.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Run a batch of any size by tiling over the largest fitting buckets
    /// (padding the tail with zero frames).
    pub fn infer_any(&self, arch: &str, input: &QTensor) -> Result<QTensor> {
        let buckets = self.buckets(arch);
        anyhow::ensure!(!buckets.is_empty(), "no variants for {arch}");
        let n = input.shape.n;
        let frame = input.shape.h * input.shape.w * input.shape.c;
        let mut out_data = Vec::with_capacity(n * 10);
        let mut done = 0usize;
        let mut classes = 10;
        while done < n {
            let remaining = n - done;
            // Largest bucket <= remaining, else smallest bucket (pad).
            let bucket = buckets
                .iter()
                .rev()
                .find(|&&b| b <= remaining)
                .or_else(|| buckets.first())
                .copied()
                .unwrap();
            let take = bucket.min(remaining);
            let mut chunk = vec![0i32; bucket * frame];
            chunk[..take * frame]
                .copy_from_slice(&input.data[done * frame..(done + take) * frame]);
            let q = QTensor::from_vec(
                Shape4::new(bucket, input.shape.h, input.shape.w, input.shape.c),
                input.exp,
                chunk,
            );
            let name = format!("{arch}_b{bucket}");
            let logits = self.model(&name)?.infer(&q)?;
            classes = logits.shape.c;
            out_data.extend_from_slice(&logits.data[..take * classes]);
            done += take;
        }
        Ok(QTensor::from_vec(Shape4::new(n, 1, 1, classes), 0, out_data))
    }
}
