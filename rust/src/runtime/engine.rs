//! The PJRT execution engine: compile once, execute many.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::quant::{QTensor, Shape4};

use super::artifacts::{Artifacts, ModelVariant};
use super::backend::{infer_tiled, InferenceBackend};

/// A compiled model variant ready to execute.
pub struct LoadedModel {
    pub variant: ModelVariant,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute on a full batch.  `input` must match the baked batch size.
    pub fn infer(&self, input: &QTensor) -> Result<QTensor> {
        let b = self.variant.batch;
        anyhow::ensure!(
            input.shape.n == b,
            "batch {} != compiled batch {b}",
            input.shape.n
        );
        let dims: Vec<i64> = self.variant.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&input.data).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<i32>()?;
        let classes = *self.variant.output_shape.last().unwrap_or(&10);
        Ok(QTensor::from_vec(Shape4::new(b, 1, 1, classes), 0, values))
    }
}

/// All compiled variants on one PJRT (CPU) client.
pub struct Engine {
    pub models: BTreeMap<String, LoadedModel>,
    platform: String,
}

impl Engine {
    /// Load and compile every variant in the artifacts directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let artifacts = Artifacts::load(dir)?;
        Self::from_artifacts(&artifacts)
    }

    /// Load and compile only the variants of one architecture — what a
    /// per-arch worker pool wants (avoids compiling other archs' HLO).
    pub fn load_arch(dir: &Path, arch: &str) -> Result<Engine> {
        let mut artifacts = Artifacts::load(dir)?;
        artifacts.models.retain(|m| m.arch == arch);
        anyhow::ensure!(
            !artifacts.models.is_empty(),
            "no compiled variants for {arch} in {}",
            dir.display()
        );
        Self::from_artifacts(&artifacts)
    }

    pub fn from_artifacts(artifacts: &Artifacts) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let platform = client.platform_name();
        let mut models = BTreeMap::new();
        for v in &artifacts.models {
            let proto = xla::HloModuleProto::from_text_file(
                v.hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", v.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", v.name))?;
            models.insert(v.name.clone(), LoadedModel { variant: v.clone(), exe });
        }
        Ok(Engine { models, platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not loaded (have: {:?})", self.models.keys()))
    }

    /// Batch-size buckets available for an arch, ascending.
    pub fn buckets(&self, arch: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .models
            .values()
            .filter(|m| m.variant.arch == arch)
            .map(|m| m.variant.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Run a batch of any size by tiling over the compiled buckets.
    ///
    /// The decomposition is [`Batcher::plan`](crate::coordinator::Batcher)
    /// via [`infer_tiled`] — the same policy the serving path uses, so the
    /// offline and online tilings cannot drift.
    pub fn infer_any(&self, arch: &str, input: &QTensor) -> Result<QTensor> {
        let buckets = self.buckets(arch);
        anyhow::ensure!(!buckets.is_empty(), "no variants for {arch}");
        let view = ArchView { engine: self, arch, buckets };
        infer_tiled(&view, input)
    }

    /// Execute one bucket-sized batch for `arch` (the compiled executable
    /// `{arch}_b{N}` must exist).
    fn infer_bucket(&self, arch: &str, input: &QTensor) -> Result<QTensor> {
        self.model(&format!("{arch}_b{}", input.shape.n))?.infer(input)
    }
}

/// Borrowed single-arch view of an [`Engine`], used to route `infer_any`
/// through the backend-generic tiling helper.
struct ArchView<'a> {
    engine: &'a Engine,
    arch: &'a str,
    buckets: Vec<usize>,
}

impl InferenceBackend for ArchView<'_> {
    fn arch(&self) -> &str {
        self.arch
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        self.engine.infer_bucket(self.arch, input)
    }
}

/// The PJRT implementation of [`InferenceBackend`]: one architecture's
/// compiled batch-bucket executables on a per-thread PJRT client.
///
/// Construct through [`PjrtFactory`](super::PjrtFactory) inside the
/// executor thread — the underlying executables are not `Send`.
pub struct PjrtBackend {
    engine: Engine,
    arch: String,
    buckets: Vec<usize>,
}

impl PjrtBackend {
    /// Load and compile the arch's variants from the artifacts directory.
    pub fn load(dir: &Path, arch: &str) -> Result<PjrtBackend> {
        let engine = Engine::load_arch(dir, arch)?;
        let buckets = engine.buckets(arch);
        Ok(PjrtBackend { engine, arch: arch.to_string(), buckets })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl InferenceBackend for PjrtBackend {
    fn arch(&self) -> &str {
        &self.arch
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        if self.buckets.contains(&input.shape.n) {
            self.engine.infer_bucket(&self.arch, input)
        } else {
            // Off-bucket batch: tile it (keeps the trait total).
            self.engine.infer_any(&self.arch, input)
        }
    }
}
