//! The artifacts manifest: what `python/compile/aot.py` exported.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::data::{IMG_C, IMG_ELEMS, IMG_H, IMG_W};
use crate::quant::{QTensor, Shape4};
use crate::util::Json;

/// One compiled model variant (architecture x baked batch size).
#[derive(Debug, Clone)]
pub struct ModelVariant {
    pub name: String,
    pub arch: String,
    pub batch: usize,
    pub hlo_path: PathBuf,
    pub input_shape: Vec<usize>,
    pub input_exp: i32,
    pub output_shape: Vec<usize>,
}

/// The probe set: cross-language correctness anchor.
#[derive(Debug, Clone)]
pub struct ProbeSet {
    /// (N, 32, 32, 3) int8-valued input @ 2^-7.
    pub input: QTensor,
    pub labels: Vec<u8>,
    /// Oracle logits per architecture: arch -> (N, 10) int32.
    pub logits: Vec<(String, Vec<i32>)>,
}

/// Parsed manifest + file access.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Json,
    pub models: Vec<ModelVariant>,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = Vec::new();
        for m in manifest
            .get("models")
            .and_then(|j| j.as_array())
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let geti = |k: &str| -> Vec<usize> {
                m.get(k)
                    .and_then(|j| j.as_array())
                    .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|x| x as usize).collect())
                    .unwrap_or_default()
            };
            models.push(ModelVariant {
                name: m.get("name").and_then(|j| j.as_str()).unwrap_or_default().into(),
                arch: m.get("arch").and_then(|j| j.as_str()).unwrap_or_default().into(),
                batch: m.get("batch").and_then(|j| j.as_i64()).unwrap_or(0) as usize,
                hlo_path: dir.join(m.get("hlo").and_then(|j| j.as_str()).unwrap_or_default()),
                input_shape: geti("input_shape"),
                input_exp: m.get("input_exp").and_then(|j| j.as_i64()).unwrap_or(-7) as i32,
                output_shape: geti("output_shape"),
            });
        }
        Ok(Artifacts { dir: dir.to_path_buf(), manifest, models })
    }

    /// Variants for one architecture, sorted by batch size.
    pub fn variants(&self, arch: &str) -> Vec<&ModelVariant> {
        let mut v: Vec<&ModelVariant> = self.models.iter().filter(|m| m.arch == arch).collect();
        v.sort_by_key(|m| m.batch);
        v
    }

    pub fn arch_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.iter().map(|m| m.arch.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Load the probe batch + oracle logits.
    pub fn probe(&self) -> Result<ProbeSet> {
        let p = self
            .manifest
            .get("probe")
            .ok_or_else(|| anyhow!("manifest missing probe"))?;
        let count = p.get("count").and_then(|j| j.as_i64()).unwrap_or(0) as usize;
        let input_raw = std::fs::read(
            self.dir.join(p.get("input").and_then(|j| j.as_str()).unwrap_or_default()),
        )?;
        anyhow::ensure!(input_raw.len() == count * IMG_ELEMS, "probe input size");
        let input = QTensor::from_vec(
            Shape4::new(count, IMG_H, IMG_W, IMG_C),
            -7,
            input_raw.iter().map(|&b| b as i8 as i32).collect(),
        );
        let labels =
            std::fs::read(self.dir.join(p.get("labels").and_then(|j| j.as_str()).unwrap_or_default()))?;
        let mut logits = Vec::new();
        if let Some(obj) = p.get("logits").and_then(|j| j.as_object()) {
            for (arch, file) in obj {
                let raw = std::fs::read(self.dir.join(file.as_str().unwrap_or_default()))?;
                let vals: Vec<i32> = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                logits.push((arch.clone(), vals));
            }
        }
        Ok(ProbeSet { input, labels, logits })
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn loads_manifest_when_artifacts_exist() {
        let dir = crate::paths::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let a = Artifacts::load(&dir).unwrap();
        assert!(!a.models.is_empty());
        assert!(a.arch_names().contains(&"resnet8".to_string()));
        let probe = a.probe().unwrap();
        assert_eq!(probe.input.shape.n, probe.labels.len());
    }
}
