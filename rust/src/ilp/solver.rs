//! The Algorithm-1 solver and a brute-force reference.

/// Per-layer optimization input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerLoad {
    pub name: String,
    /// Computations per frame, Eq. 8: `c_i = oh*ow*och*ich*fh*fw`.
    pub macs: u64,
    /// Filter taps `k_i = fh*fw` (Eq. 10).
    pub taps: usize,
    /// Output channels (upper bound and divisor constraint for och_par).
    pub och: usize,
    /// Output-width unroll (2 with DSP packing at 8 bits, else 1).
    pub ow_par: usize,
}

impl LayerLoad {
    /// Feasible unroll factors: one per distinct group count
    /// `och_groups = ceil(och / och_par)` — `p = ceil(och/g)` is the
    /// cheapest unroll achieving `g` groups (the last group may be
    /// partially filled, as the generated HLS allows).
    pub fn candidates(&self) -> Vec<usize> {
        let mut c: Vec<usize> = (1..=self.och).map(|g| self.och.div_ceil(g)).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Peak MACs per cycle at a given unroll (Eq. 9).
    pub fn cp(&self, och_par: usize) -> u64 {
        (self.taps * och_par * self.ow_par) as u64
    }

    /// DSPs consumed at a given unroll: one DSP per tap per output channel
    /// (packing makes this independent of ow_par, Section III-C).
    pub fn dsps(&self, och_par: usize) -> u64 {
        (self.taps * och_par) as u64
    }

    /// Cycles per frame at a given unroll: the main loop iterates over
    /// `oh*ow/ow_par` window positions x `ich` x `och_groups`, i.e.
    /// `(c_i / och) * ceil(och / p) / ow_par` (Eq. 11 generalized to
    /// partial groups).
    pub fn cycles(&self, och_par: usize) -> u64 {
        let per_group = self.macs / self.och as u64 / self.taps as u64;
        (per_group * self.och.div_ceil(och_par) as u64).div_ceil(self.ow_par as u64)
    }
}

/// One layer's solved configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerAlloc {
    pub name: String,
    pub och_par: usize,
    pub cp: u64,
    pub dsps: u64,
    pub cycles: u64,
}

/// A solved allocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub layers: Vec<LayerAlloc>,
    /// Steady-state initiation interval = max layer cycles (the slowest
    /// concurrent process limits throughput, Section III-B).
    pub cycles_per_frame: u64,
    pub dsps_used: u64,
    pub dsp_budget: u64,
}

impl Allocation {
    /// Frames per second at a fabric clock.
    pub fn fps(&self, clock_mhz: f64) -> f64 {
        clock_mhz * 1e6 / self.cycles_per_frame as f64
    }

    /// Effective Gops/s (2 ops per MAC) for a model with `total_macs`.
    pub fn gops(&self, clock_mhz: f64, total_macs: u64) -> f64 {
        2.0 * total_macs as f64 * self.fps(clock_mhz) / 1e9
    }

    pub fn layer(&self, name: &str) -> Option<&LayerAlloc> {
        self.layers.iter().find(|l| l.name == name)
    }
}

/// Algorithm 1: maximize the network throughput `Th` subject to
/// `Σ cp_i ≤ N_PAR` (expressed in DSPs; packing halves the DSP cost of a
/// MAC/cycle).
///
/// The paper phrases the objective through the bottleneck layer `i_max`
/// (Eq. 12) and balances every other layer to its rate (Eq. 14).  With the
/// divisor-quantized unroll candidates, the bottleneck that binds may also
/// be a layer whose maximum unroll caps out (e.g. the 16-channel stem on
/// large budgets); so we enumerate every *achievable throughput level* —
/// the union of all layers' `cp(p)/c` values — and for each level give
/// every layer its cheapest unroll meeting the level.  The best feasible
/// level is optimal: throughput is the min over layers, each layer's cost
/// is monotone in its rate, so any optimum is reproduced at its own
/// effective level.  `brute_force` cross-checks this in tests.
///
/// Returns `None` only if even the all-minimal allocation exceeds the
/// budget (no feasible design).
pub fn solve(loads: &[LayerLoad], dsp_budget: u64) -> Option<Allocation> {
    assert!(!loads.is_empty());
    // Candidate cycle targets: every cycles-per-frame value any layer can
    // realize.  The optimum's bottleneck cycles is one of these.
    let mut levels: Vec<u64> = loads
        .iter()
        .flat_map(|l| l.candidates().into_iter().map(|p| l.cycles(p)))
        .collect();
    levels.sort_unstable();
    levels.dedup();

    let mut best: Option<Allocation> = None;
    for &target in &levels {
        let Some(layers) = allocate_for_target(loads, target) else { continue };
        let dsps_used: u64 = layers.iter().map(|l| l.dsps).sum();
        if dsps_used > dsp_budget {
            continue;
        }
        let cycles = layers.iter().map(|l| l.cycles).max().unwrap();
        let a = Allocation { layers, cycles_per_frame: cycles, dsps_used, dsp_budget };
        let better = match &best {
            None => true,
            Some(b) => {
                a.cycles_per_frame < b.cycles_per_frame
                    || (a.cycles_per_frame == b.cycles_per_frame && a.dsps_used < b.dsps_used)
            }
        };
        if better {
            best = Some(a);
        }
    }
    best
}

/// Give every layer its cheapest unroll with `cycles_i(p) <= target`
/// (Eq. 14's balancing).  Returns None if some layer cannot reach the
/// target even fully unrolled (that target is unachievable).
fn allocate_for_target(loads: &[LayerLoad], target: u64) -> Option<Vec<LayerAlloc>> {
    let mut out = Vec::with_capacity(loads.len());
    for l in loads {
        let och_par = l.candidates().into_iter().find(|&p| l.cycles(p) <= target)?;
        out.push(LayerAlloc {
            name: l.name.clone(),
            och_par,
            cp: l.cp(och_par),
            dsps: l.dsps(och_par),
            cycles: l.cycles(och_par),
        });
    }
    Some(out)
}

/// Exhaustive reference solver (exponential; tests only, <= ~5 layers).
/// Maximizes throughput (min cycles), then minimizes DSPs.
pub fn brute_force(loads: &[LayerLoad], dsp_budget: u64) -> Option<Allocation> {
    let cand: Vec<Vec<usize>> = loads.iter().map(|l| l.candidates()).collect();
    let mut idx = vec![0usize; loads.len()];
    let mut best: Option<Allocation> = None;
    loop {
        let layers: Vec<LayerAlloc> = loads
            .iter()
            .zip(&idx)
            .zip(&cand)
            .map(|((l, &j), c)| {
                let p = c[j];
                LayerAlloc { name: l.name.clone(), och_par: p, cp: l.cp(p), dsps: l.dsps(p), cycles: l.cycles(p) }
            })
            .collect();
        let dsps_used: u64 = layers.iter().map(|l| l.dsps).sum();
        if dsps_used <= dsp_budget {
            let cycles = layers.iter().map(|l| l.cycles).max().unwrap();
            let a = Allocation { layers, cycles_per_frame: cycles, dsps_used, dsp_budget };
            let better = match &best {
                None => true,
                Some(b) => {
                    a.cycles_per_frame < b.cycles_per_frame
                        || (a.cycles_per_frame == b.cycles_per_frame && a.dsps_used < b.dsps_used)
                }
            };
            if better {
                best = Some(a);
            }
        }
        // Odometer increment.
        let mut k = 0;
        loop {
            if k == idx.len() {
                return best;
            }
            idx[k] += 1;
            if idx[k] < cand[k].len() {
                break;
            }
            idx[k] = 0;
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn load(name: &str, macs: u64, taps: usize, och: usize) -> LayerLoad {
        LayerLoad { name: name.into(), macs, taps, och, ow_par: 2 }
    }

    #[test]
    fn balances_two_layers() {
        // Layer b has 2x the work; it should get ~2x the parallelism.
        let loads = vec![load("a", 1_000_000, 9, 32), load("b", 2_000_000, 9, 32)];
        let a = solve(&loads, 500).unwrap();
        let pa = a.layer("a").unwrap().och_par;
        let pb = a.layer("b").unwrap().och_par;
        assert!(pb >= 2 * pa, "a={pa} b={pb}");
        assert!(a.dsps_used <= 500);
    }

    #[test]
    fn matches_brute_force_throughput() {
        forall("solve == brute force (throughput)", 60, |rng| {
            let n = rng.range_i64(1, 4) as usize;
            let loads: Vec<LayerLoad> = (0..n)
                .map(|i| {
                    let och = [4usize, 8, 16][rng.below(3) as usize];
                    let taps = [1usize, 9][rng.below(2) as usize];
                    load(&format!("l{i}"), rng.range_i64(10_000, 2_000_000) as u64, taps, och)
                })
                .collect();
            let budget = rng.range_i64(16, 600) as u64;
            let s = solve(&loads, budget);
            let b = brute_force(&loads, budget);
            match (s, b) {
                (None, None) => {}
                (Some(s), Some(b)) => {
                    assert_eq!(
                        s.cycles_per_frame, b.cycles_per_frame,
                        "solve {} vs brute {}",
                        s.cycles_per_frame, b.cycles_per_frame
                    );
                    assert!(s.dsps_used <= budget);
                }
                (s, b) => panic!("feasibility mismatch: {s:?} vs {b:?}"),
            }
        });
    }

    #[test]
    fn infeasible_when_budget_below_minimum() {
        // Minimal config needs taps DSPs per layer.
        let loads = vec![load("a", 1000, 9, 8), load("b", 1000, 9, 8)];
        assert!(solve(&loads, 17).is_none()); // needs >= 18
        assert!(solve(&loads, 18).is_some());
    }

    #[test]
    fn throughput_monotone_in_budget() {
        let loads = vec![
            load("a", 500_000, 9, 64),
            load("b", 2_000_000, 9, 64),
            load("c", 1_000_000, 1, 64),
        ];
        let mut prev = u64::MAX;
        for budget in [64u64, 128, 256, 512, 1024, 2048] {
            if let Some(a) = solve(&loads, budget) {
                assert!(a.cycles_per_frame <= prev, "budget {budget}");
                prev = a.cycles_per_frame;
            }
        }
        assert!(prev < u64::MAX);
    }
}
