//! Throughput optimization (paper Section III-E, Algorithm 1).
//!
//! Chooses the per-layer unroll factors `och_i^par` (the number of PE
//! groups) that maximize network throughput under the board's DSP budget
//! `N_PAR`.  Because the dataflow accelerator's throughput is the minimum
//! over layers of `Th_i = cp_i / c_i` (Eq. 11), and the per-layer cost is
//! monotone in `och_i^par`, the ILP reduces to: pick the bottleneck
//! layer's parallelism, derive every other layer's minimal parallelism
//! that matches the bottleneck's throughput (Eq. 14's balancing), and take
//! the largest feasible configuration (Eq. 12/13).  `solve` implements
//! exactly that; `brute_force` enumerates for small instances to prove
//! optimality in tests.

mod solver;

pub use solver::{brute_force, solve, Allocation, LayerAlloc, LayerLoad};

use crate::models::ArchSpec;

/// Build the ILP inputs from an architecture (Eq. 8 per conv layer).
///
/// `ow_par` is 2 for 8-bit quantization (packing, Section III-C); the
/// baselines pass 1.
pub fn loads_from_arch(arch: &ArchSpec, ow_par: usize) -> Vec<LayerLoad> {
    arch.conv_layers()
        .into_iter()
        .map(|c| LayerLoad {
            name: c.name.clone(),
            macs: c.macs(),
            taps: c.taps(),
            och: c.cout,
            ow_par,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet20, resnet8};

    #[test]
    fn loads_cover_all_convs() {
        let arch = resnet8();
        let loads = loads_from_arch(&arch, 2);
        assert_eq!(loads.len(), 9);
        assert!(loads.iter().all(|l| l.macs > 0));
    }

    #[test]
    fn paper_fps_shapes() {
        // The solved allocations should land near the paper's Table 3 FPS
        // when scaled by the board clocks (shape check, generous band —
        // the full model with resource closure lives in hls::resources).
        let cases = [
            ("resnet8", 360u64, 214.0, 12_971.0),  // Ultra96
            ("resnet20", 360u64, 214.0, 3_254.0),  // Ultra96
            ("resnet8", 1248u64, 274.0, 30_153.0), // KV260 (och caps bind)
        ];
        for (name, n_par, mhz, paper_fps) in cases {
            let arch = if name == "resnet8" { resnet8() } else { resnet20() };
            let loads = loads_from_arch(&arch, 2);
            let alloc = solve(&loads, n_par).expect("feasible");
            let fps = alloc.fps(mhz);
            let ratio = fps / paper_fps;
            assert!(
                (0.5..=2.0).contains(&ratio),
                "{name}@{n_par}: model {fps:.0} FPS vs paper {paper_fps} (ratio {ratio:.2})"
            );
        }
    }
}
