//! Loop merge (paper Fig. 12b): in a residual block *with* a downsample
//! convolution, the pointwise skip conv is absorbed into the task of the
//! long branch's first convolution.
//!
//! Pattern:
//!
//! ```text
//!        t ──> ds(1x1 conv) ──────┐
//!        t ──> conv0 ──> ...      v
//!                              (consumer of ds, e.g. the Add)
//! ```
//!
//! Both `ds` and `conv0` read the same tensor `t`.  After the pass, `ds`'s
//! computation lives inside `conv0`'s task (both loops iterate over the
//! same input stream, so they merge at identical trip counts) and the
//! merged output is exposed on `conv0` port 1.  This removes one endpoint
//! of `t` — the skip branch no longer needs its own copy of the stream —
//! which is the first half of the paper's buffering reduction (Eq. 23).

use crate::graph::{Edge, Graph, MergedDownsample, Op};

use super::relu_merge::rewire;

/// Apply the pass; returns the number of downsample convs merged.
pub fn loop_merge(g: &mut Graph) -> usize {
    let mut merged = 0;
    let ids: Vec<usize> = g.live().map(|n| n.id).collect();
    for ds_id in ids {
        // Candidate ds: a 1x1 conv whose input tensor is also read by
        // another (larger-filter) conv — the long branch's conv0.
        let (t, ds_attrs, ds_name) = {
            let n = g.node(ds_id);
            if n.dead {
                continue;
            }
            let a = match &n.op {
                Op::Conv(a) if a.k == 1 && a.merged_downsample.is_none() && !a.forwards_input => a.clone(),
                _ => continue,
            };
            (n.inputs[0].0, a, n.name.clone())
        };
        let siblings: Vec<usize> = g
            .consumers(t)
            .into_iter()
            .filter(|&c| c != ds_id)
            .filter(|&c| matches!(&g.node(c).op, Op::Conv(a) if a.k > 1 && a.merged_downsample.is_none()))
            .collect();
        let Some(&host) = siblings.first() else { continue };

        // Absorb ds into the host conv's task.
        if let Op::Conv(a) = &mut g.node_mut(host).op {
            a.merged_downsample = Some(MergedDownsample {
                name: ds_name,
                cout: ds_attrs.cout,
                k: ds_attrs.k,
                stride: ds_attrs.stride,
                pad: ds_attrs.pad,
                w_exp: ds_attrs.w_exp,
                out_exp: ds_attrs.out_exp,
            });
        }
        rewire(g, Edge::new(ds_id, 0), Edge::new(host, 1));
        g.node_mut(ds_id).dead = true;
        merged += 1;
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ConvAttrs, InputRole};

    fn attrs(cin: usize, cout: usize, k: usize, stride: usize) -> ConvAttrs {
        ConvAttrs {
            cin, cout, k, stride, pad: if k == 3 { 1 } else { 0 }, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
        }
    }

    #[test]
    fn merges_downsample_block() {
        // t -> ds(1x1 s2), t -> c0(3x3 s2) -> c1(3x3) ; add(c1, ds)
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let ds = g.add_simple("ds", Op::Conv(attrs(4, 8, 1, 2)), &[Edge::new(i, 0)]);
        let c0 = g.add_simple("c0", Op::Conv(attrs(4, 8, 3, 2)), &[Edge::new(i, 0)]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(8, 8, 3, 1)), &[Edge::new(c0, 0)]);
        g.add(
            "add",
            Op::Add { out_exp: -5 },
            vec![(Edge::new(c1, 0), InputRole::Data), (Edge::new(ds, 0), InputRole::Data)],
        );
        assert_eq!(loop_merge(&mut g), 1);
        assert!(g.node(ds).dead);
        let host = g.find("c0").unwrap();
        match &g.node(host).op {
            Op::Conv(a) => {
                let m = a.merged_downsample.as_ref().unwrap();
                assert_eq!(m.name, "ds");
                assert_eq!(m.cout, 8);
            }
            _ => unreachable!(),
        }
        // Add's second input now reads c0 port 1.
        let add = g.find("add").unwrap();
        assert_eq!(g.node(add).inputs[1].0, Edge::new(host, 1));
        g.compact();
        assert!(g.validate().is_ok());
    }

    #[test]
    fn ignores_lone_pointwise_conv() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        g.add_simple("pw", Op::Conv(attrs(4, 8, 1, 1)), &[Edge::new(i, 0)]);
        assert_eq!(loop_merge(&mut g), 0);
    }
}
