//! Temporal reuse (paper Fig. 12a): in a residual block *without* a
//! downsample, the identity skip branch re-uses conv0's window buffer
//! instead of buffering the tensor a second time.
//!
//! Pattern:
//!
//! ```text
//!     t ──────────────────────┐        (identity skip)
//!     t ──> conv0 ──> conv1 ──┴─> add
//! ```
//!
//! `t` has two endpoints: conv0's window buffer and the skip stream.  The
//! pass sets `forwards_input` on conv0 — its window buffer forwards each
//! activation on port 1 once the value has been fully consumed by the
//! sliding window — and rewires the skip consumer to that port.  The skip
//! branch's buffering collapses from the receptive-field bound (Eq. 21)
//! to conv1's own window buffer (Eq. 22): the R_sc = 0.5 headline.

use crate::graph::{Graph, Op};

/// Apply the pass; returns the number of skip branches rewired.
pub fn temporal_reuse(g: &mut Graph) -> usize {
    let mut rewired = 0;
    let ids: Vec<usize> = g.live().map(|n| n.id).collect();
    for add_id in ids {
        // Pattern root: an Add node (the residual merge).
        let (long_edge, skip_edge) = {
            let n = g.node(add_id);
            if n.dead || !matches!(n.op, Op::Add { .. }) {
                continue;
            }
            // Multi-input merges (extra long skips) stay naive: rewiring
            // just one operand onto conv0's forwarding port would leave a
            // hybrid the add-fusion pass cannot absorb.
            if n.inputs.len() != 2 {
                continue;
            }
            (n.inputs[0].0, n.inputs[1].0)
        };
        // The long branch input must be a conv (conv1); walk back to conv0.
        let conv1 = long_edge.node;
        if !matches!(g.node(conv1).op, Op::Conv(_)) {
            continue;
        }
        let conv0_edge = g.node(conv1).inputs[0].0;
        let conv0 = conv0_edge.node;
        if !matches!(g.node(conv0).op, Op::Conv(_)) {
            continue;
        }
        // Identity skip: the skip edge must be exactly conv0's input.
        if g.node(conv0).inputs[0].0 != skip_edge || skip_edge.port != 0 {
            continue;
        }
        // Set forwarding on conv0 and move the skip consumer to port 1.
        if let Op::Conv(a) = &mut g.node_mut(conv0).op {
            if a.forwards_input || a.merged_downsample.is_some() {
                continue;
            }
            a.forwards_input = true;
        }
        let new_edge = crate::graph::Edge::new(conv0, 1);
        for (e, _) in &mut g.node_mut(add_id).inputs {
            if *e == skip_edge {
                *e = new_edge;
            }
        }
        rewired += 1;
    }
    rewired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ConvAttrs, Edge, InputRole};

    fn attrs(c: usize) -> ConvAttrs {
        ConvAttrs {
            cin: c, cout: c, k: 3, stride: 1, pad: 1, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
        }
    }

    #[test]
    fn rewires_identity_skip() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let c0 = g.add_simple("c0", Op::Conv(attrs(4)), &[Edge::new(i, 0)]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(4)), &[Edge::new(c0, 0)]);
        let add = g.add(
            "add",
            Op::Add { out_exp: -5 },
            vec![(Edge::new(c1, 0), InputRole::Data), (Edge::new(i, 0), InputRole::Data)],
        );
        assert_eq!(temporal_reuse(&mut g), 1);
        assert!(matches!(&g.node(c0).op, Op::Conv(a) if a.forwards_input));
        assert_eq!(g.node(add).inputs[1].0, Edge::new(c0, 1));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn ignores_downsample_skip() {
        // Skip through a conv is not an identity skip; loop_merge handles it.
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let ds = g.add_simple("ds", Op::Conv(ConvAttrs { k: 1, pad: 0, ..attrs(4) }), &[Edge::new(i, 0)]);
        let c0 = g.add_simple("c0", Op::Conv(attrs(4)), &[Edge::new(i, 0)]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(4)), &[Edge::new(c0, 0)]);
        g.add(
            "add",
            Op::Add { out_exp: -5 },
            vec![(Edge::new(c1, 0), InputRole::Data), (Edge::new(ds, 0), InputRole::Data)],
        );
        assert_eq!(temporal_reuse(&mut g), 0);
    }
}
