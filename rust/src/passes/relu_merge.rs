//! ReLU merging: `Conv -> Relu` becomes `Conv{relu: true}`.
//!
//! The paper merges ReLU (and BN) with convolutions before code generation
//! (Section III-B: the code generation step works on the graph "after ReLU
//! and batch normalization were merged with convolutional layers").  The
//! fused ReLU is applied to the 32-bit accumulator before requantization,
//! which is exactly equivalent to applying it to the int8 output when the
//! output scale is non-negative (requantization is monotone and maps 0 to
//! 0) — the property test in `rust/tests/props.rs` checks this identity.

use crate::graph::{Edge, Graph, Op};

/// Apply the pass; returns the number of ReLU nodes merged.
pub fn relu_merge(g: &mut Graph) -> usize {
    let mut merged = 0;
    let ids: Vec<usize> = g.live().map(|n| n.id).collect();
    for id in ids {
        // Pattern: live Relu whose single input is a Conv with no other
        // consumers of port 0 (a conv feeding both a ReLU and something
        // else cannot fuse — the other consumer would see pre-ReLU data).
        let (conv_id, relu_id) = {
            let n = g.node(id);
            if n.dead || !matches!(n.op, Op::Relu) {
                continue;
            }
            let (src, _) = n.inputs[0];
            if src.port != 0 {
                continue;
            }
            match &g.node(src.node).op {
                Op::Conv(_) => {}
                _ => continue,
            }
            if g.consumers(src).len() != 1 {
                continue;
            }
            (src.node, n.id)
        };
        // Fuse: set relu on the conv, rewire ReLU's consumers to the conv.
        if let Op::Conv(a) = &mut g.node_mut(conv_id).op {
            if a.relu {
                continue; // already fused
            }
            a.relu = true;
        }
        rewire(g, Edge::new(relu_id, 0), Edge::new(conv_id, 0));
        g.node_mut(relu_id).dead = true;
        merged += 1;
    }
    merged
}

/// Replace every use of `from` with `to`.
pub(crate) fn rewire(g: &mut Graph, from: Edge, to: Edge) {
    for n in &mut g.nodes {
        if n.dead {
            continue;
        }
        for (e, _) in &mut n.inputs {
            if *e == from {
                *e = to;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ConvAttrs, InputRole};

    fn conv_attrs() -> ConvAttrs {
        ConvAttrs {
            cin: 3, cout: 4, k: 3, stride: 1, pad: 1, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
        }
    }

    #[test]
    fn merges_simple_chain() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 3, exp: -7 }, &[]);
        let c = g.add_simple("c", Op::Conv(conv_attrs()), &[Edge::new(i, 0)]);
        let r = g.add_simple("r", Op::Relu, &[Edge::new(c, 0)]);
        let _p = g.add_simple("p", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(r, 0)]);
        assert_eq!(relu_merge(&mut g), 1);
        g.compact();
        assert!(g.validate().is_ok());
        assert_eq!(g.count_kind("relu"), 0);
        let c = g.find("c").unwrap();
        assert!(matches!(&g.node(c).op, Op::Conv(a) if a.relu));
        let p = g.find("p").unwrap();
        assert_eq!(g.node(p).inputs[0].0, Edge::new(c as usize, 0));
        let _ = p;
    }

    #[test]
    fn refuses_when_conv_has_other_consumers() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 3, exp: -7 }, &[]);
        let c = g.add_simple("c", Op::Conv(conv_attrs()), &[Edge::new(i, 0)]);
        let r = g.add_simple("r", Op::Relu, &[Edge::new(c, 0)]);
        // second consumer of the conv's raw output
        let c2 = g.add_simple(
            "c2",
            Op::Conv(ConvAttrs { cin: 4, ..conv_attrs() }),
            &[Edge::new(c, 0)],
        );
        g.add(
            "add",
            Op::Add { out_exp: -5 },
            vec![(Edge::new(r, 0), InputRole::Data), (Edge::new(c2, 0), InputRole::Data)],
        );
        assert_eq!(relu_merge(&mut g), 0);
    }
}
