//! BatchNorm folding: `Conv -> BatchNorm` becomes a single Conv with
//! rescaled weights and shifted bias (paper Section III-A: "the batch
//! normalization layers are merged with the quantized convolution layers").
//!
//! BN in inference form is `y = scale * x + shift` per channel (scale and
//! shift already absorb mean/var/eps/gamma/beta).  Folding into the conv:
//!
//! ```text
//! W'[kh,kw,ci,co] = scale[co] * W[kh,kw,ci,co]
//! b'[co]          = scale[co] * b[co] + shift[co]
//! ```
//!
//! This pass is *numeric*: it needs float parameters, so it operates on a
//! side table of float conv params (the training-time view).  The deployed
//! quantized graphs never contain BN nodes — the paper (and our train.py)
//! fold + retrain before export — but the pass is part of the flow and is
//! exercised by tests that fold a float graph and compare outputs.

use std::collections::BTreeMap;

use crate::graph::{Edge, Graph, Op};

use super::relu_merge::rewire;

/// Float parameters of a conv layer during the fold (training-time view).
#[derive(Debug, Clone, PartialEq)]
pub struct FloatConvParams {
    /// (KH, KW, CIN, COUT) row-major.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
}

impl FloatConvParams {
    #[inline]
    pub fn w_at(&self, kh: usize, kw: usize, ci: usize, co: usize) -> f32 {
        self.w[((kh * self.kw + kw) * self.cin + ci) * self.cout + co]
    }
}

/// Fold every `Conv -> BatchNorm` pair; returns the number folded.
///
/// `params` maps conv node names to their float parameters and is updated
/// in place.
pub fn bn_fold(g: &mut Graph, params: &mut BTreeMap<String, FloatConvParams>) -> usize {
    let mut folded = 0;
    let ids: Vec<usize> = g.live().map(|n| n.id).collect();
    for id in ids {
        let (conv_id, bn_id, scale, shift) = {
            let n = g.node(id);
            if n.dead {
                continue;
            }
            let bn = match &n.op {
                Op::BatchNorm(b) => b.clone(),
                _ => continue,
            };
            let (src, _) = n.inputs[0];
            if src.port != 0 || !matches!(g.node(src.node).op, Op::Conv(_)) {
                continue;
            }
            if g.consumers(src).len() != 1 {
                continue; // conv output also consumed raw elsewhere
            }
            (src.node, n.id, bn.scale, bn.shift)
        };
        let name = g.node(conv_id).name.clone();
        if let Some(p) = params.get_mut(&name) {
            assert_eq!(p.cout, scale.len(), "{name}: BN channels mismatch");
            for idx in 0..p.w.len() {
                let co = idx % p.cout;
                p.w[idx] *= scale[co];
            }
            for co in 0..p.cout {
                p.b[co] = p.b[co] * scale[co] + shift[co];
            }
        }
        rewire(g, Edge::new(bn_id, 0), Edge::new(conv_id, 0));
        g.node_mut(bn_id).dead = true;
        folded += 1;
    }
    folded
}

/// Reference float conv for the fold-correctness test.
#[cfg(test)]
fn conv_f32(x: &[f32], h: usize, w: usize, p: &FloatConvParams, stride: usize, pad: usize) -> Vec<f32> {
    let oh = (h + 2 * pad - p.kh) / stride + 1;
    let ow = (w + 2 * pad - p.kw) / stride + 1;
    let mut out = vec![0f32; oh * ow * p.cout];
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..p.cout {
                let mut acc = p.b[co];
                for ky in 0..p.kh {
                    for kx in 0..p.kw {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                            continue;
                        }
                        for ci in 0..p.cin {
                            acc += x[((iy - pad) * w + (ix - pad)) * p.cin + ci]
                                * p.w_at(ky, kx, ci, co);
                        }
                    }
                }
                out[(oy * ow + ox) * p.cout + co] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{BatchNormAttrs, ConvAttrs};
    use crate::util::Lcg64;

    fn rand_params(rng: &mut Lcg64, kh: usize, kw: usize, cin: usize, cout: usize) -> FloatConvParams {
        FloatConvParams {
            w: (0..kh * kw * cin * cout).map(|_| rng.next_f64() as f32 - 0.5).collect(),
            b: (0..cout).map(|_| rng.next_f64() as f32 - 0.5).collect(),
            kh, kw, cin, cout,
        }
    }

    #[test]
    fn fold_is_numerically_exact() {
        let mut rng = Lcg64::new(99);
        let (h, w, cin, cout) = (6usize, 6usize, 3usize, 4usize);
        let p = rand_params(&mut rng, 3, 3, cin, cout);
        let scale: Vec<f32> = (0..cout).map(|_| rng.next_f64() as f32 + 0.5).collect();
        let shift: Vec<f32> = (0..cout).map(|_| rng.next_f64() as f32 - 0.5).collect();
        let x: Vec<f32> = (0..h * w * cin).map(|_| rng.next_f64() as f32 - 0.5).collect();

        // Unfolded: conv then BN.
        let y = conv_f32(&x, h, w, &p, 1, 1);
        let want: Vec<f32> = y
            .iter()
            .enumerate()
            .map(|(i, &v)| v * scale[i % cout] + shift[i % cout])
            .collect();

        // Build graph, fold, re-run conv with folded params.
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h, w, c: cin, exp: -7 }, &[]);
        let c = g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin, cout, k: 3, stride: 1, pad: 1, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        g.add_simple(
            "bn",
            Op::BatchNorm(BatchNormAttrs { channels: cout, scale: scale.clone(), shift: shift.clone() }),
            &[Edge::new(c, 0)],
        );
        let mut params = BTreeMap::new();
        params.insert("c".to_string(), p);
        assert_eq!(bn_fold(&mut g, &mut params), 1);
        g.compact();
        assert_eq!(g.count_kind("batchnorm"), 0);

        let got = conv_f32(&x, h, w, &params["c"], 1, 1);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
