//! Structural graph equivalence (up to node ids and dead nodes).
//!
//! Used to assert that the optimization pipeline transforms the
//! unoptimized builder's graph into exactly the optimized builder's graph.

use std::collections::BTreeMap;

use crate::graph::{Graph, Op};

/// True if the two graphs are isomorphic under name matching: same live
/// node names, same ops (attribute-exact), and same named input edges.
pub fn equivalent(a: &Graph, b: &Graph) -> bool {
    let name_map = |g: &Graph| -> BTreeMap<String, usize> {
        g.live().map(|n| (n.name.clone(), n.id)).collect()
    };
    let an = name_map(a);
    let bn = name_map(b);
    if an.len() != bn.len() || an.keys().ne(bn.keys()) {
        return false;
    }
    for (name, &aid) in &an {
        let na = a.node(aid);
        let nb = b.node(bn[name]);
        if !ops_equal(&na.op, &nb.op) {
            return false;
        }
        if na.inputs.len() != nb.inputs.len() {
            return false;
        }
        for ((ea, ra), (eb, rb)) in na.inputs.iter().zip(&nb.inputs) {
            if ra != rb || ea.port != eb.port {
                return false;
            }
            if a.node(ea.node).name != b.node(eb.node).name {
                return false;
            }
        }
    }
    true
}

fn ops_equal(a: &Op, b: &Op) -> bool {
    a == b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ConvAttrs, Edge};

    fn conv(c: usize) -> Op {
        Op::Conv(ConvAttrs {
            cin: c, cout: c, k: 3, stride: 1, pad: 1, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
        })
    }

    #[test]
    fn identical_graphs_are_equivalent() {
        let build = || {
            let mut g = Graph::new();
            let i = g.add_simple("in", Op::Input { h: 4, w: 4, c: 2, exp: -7 }, &[]);
            g.add_simple("c", conv(2), &[Edge::new(i, 0)]);
            g
        };
        assert!(equivalent(&build(), &build()));
    }

    #[test]
    fn id_permutation_is_equivalent() {
        let mut a = Graph::new();
        let i = a.add_simple("in", Op::Input { h: 4, w: 4, c: 2, exp: -7 }, &[]);
        a.add_simple("c", conv(2), &[Edge::new(i, 0)]);

        // Same graph with a dead node inserted before (shifting ids).
        let mut b = Graph::new();
        let dead = b.add_simple("zombie", Op::Relu, &[]);
        b.node_mut(dead).dead = true;
        let i = b.add_simple("in", Op::Input { h: 4, w: 4, c: 2, exp: -7 }, &[]);
        b.add_simple("c", conv(2), &[Edge::new(i, 0)]);
        assert!(equivalent(&a, &b));
    }

    #[test]
    fn attr_difference_detected() {
        let mut a = Graph::new();
        let i = a.add_simple("in", Op::Input { h: 4, w: 4, c: 2, exp: -7 }, &[]);
        a.add_simple("c", conv(2), &[Edge::new(i, 0)]);
        let mut b = Graph::new();
        let i2 = b.add_simple("in", Op::Input { h: 4, w: 4, c: 2, exp: -7 }, &[]);
        let cid = b.add_simple("c", conv(2), &[Edge::new(i2, 0)]);
        if let Op::Conv(attrs) = &mut b.node_mut(cid).op {
            attrs.relu = true;
        }
        assert!(!equivalent(&a, &b));
    }
}
