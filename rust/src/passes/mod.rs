//! Graph optimization passes (paper Sections III-A and III-G).
//!
//! Pipeline order (the paper's flow, Fig. 2 "graph optimization"):
//!
//! 1. [`bn_fold`] — merge BatchNorm into the preceding convolution
//!    (Section III-A: done after training, before export);
//! 2. [`relu_merge`] — fuse standalone ReLU nodes into the producing conv;
//! 3. [`loop_merge`] — residual blocks *with* downsample: compute the
//!    pointwise skip conv inside conv0's task (Fig. 12b);
//! 4. [`temporal_reuse`] — residual blocks *without* downsample: forward
//!    the skip tensor out of conv0's window buffer instead of buffering it
//!    twice (Fig. 12a);
//! 5. [`add_fusion`] — delete the Add node by initializing conv1's
//!    accumulator with the (aligned) skip value (Fig. 13), fusing the
//!    post-add ReLU.
//!
//! The end state must equal `models::build_optimized_graph` — asserted by
//! `equivalent` in tests — and the whole pipeline must be numerics- and
//! shape-preserving (property tests in `rust/tests/props.rs`, numeric
//! equality via `model.unoptimized_ref_forward` on the Python side and
//! `sim::golden` here).

mod add_fusion;
mod bn_fold;
mod equivalence;
mod loop_merge;
mod relu_merge;
mod temporal_reuse;

pub use add_fusion::{add_fusion, is_fusable_residual};
pub use bn_fold::{bn_fold, FloatConvParams};
pub use equivalence::equivalent;
pub use loop_merge::loop_merge;
pub use relu_merge::relu_merge;
pub use temporal_reuse::temporal_reuse;

use crate::graph::Graph;

/// Statistics of one pipeline run.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct PassStats {
    pub bn_folded: usize,
    pub relu_merged: usize,
    pub loops_merged: usize,
    pub reuses: usize,
    pub adds_fused: usize,
}

/// Run the full residual-optimization pipeline in the published order.
/// (BN folding is numeric and runs separately via [`bn_fold`] when float
/// parameters are in play; graphs built from quantized checkpoints have no
/// BN nodes left.)
pub fn optimize(g: &mut Graph) -> PassStats {
    let mut stats = PassStats::default();
    stats.relu_merged = relu_merge(g);
    stats.loops_merged = loop_merge(g);
    stats.reuses = temporal_reuse(g);
    stats.adds_fused = add_fusion(g);
    g.compact();
    debug_assert!(g.validate().is_ok());
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::models::{
        build_optimized_graph, build_unoptimized_graph, default_exps, longskipnet, resnet20,
        resnet8, skipnet, tiednet,
    };

    #[test]
    fn pipeline_reaches_optimized_form_resnet8() {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = optimize(&mut g);
        assert_eq!(stats.loops_merged, 2, "resnet8 has 2 downsample blocks");
        assert_eq!(stats.reuses, 1, "resnet8 has 1 identity-skip block");
        assert_eq!(stats.adds_fused, 3);
        let want = build_optimized_graph(&arch, &act, &w);
        assert!(equivalent(&g, &want), "got:\n{g}\nwant:\n{want}");
    }

    #[test]
    fn pipeline_reaches_optimized_form_resnet20() {
        let arch = resnet20();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = optimize(&mut g);
        assert_eq!(stats.loops_merged, 2);
        assert_eq!(stats.reuses, 7);
        assert_eq!(stats.adds_fused, 9);
        let want = build_optimized_graph(&arch, &act, &w);
        assert!(equivalent(&g, &want), "got:\n{g}\nwant:\n{want}");
    }

    #[test]
    fn pipeline_reaches_optimized_form_on_general_topologies() {
        // skipnet: the 3-operand merge (identity + long skip to the stem)
        // must survive as a naive island while its neighbors fuse.
        let arch = skipnet();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = optimize(&mut g);
        assert_eq!(stats.loops_merged, 1, "r2's projection merges");
        assert_eq!(stats.reuses, 1, "r0's identity skip forwards");
        assert_eq!(stats.adds_fused, 2, "r1's multi-input add must NOT fuse");
        assert_eq!(g.count_kind("add"), 1);
        let want = build_optimized_graph(&arch, &act, &w);
        assert!(equivalent(&g, &want), "got:\n{g}\nwant:\n{want}");

        // longskipnet: r1's merge has the two-operand single-skip *shape*
        // the fused dataflow matches, but its skip is a long skip back to
        // the stem — fusing it would pair an Eq. 22 SkipInit FIFO with
        // full-frame skew, so it must survive as a naive island.
        let arch = longskipnet();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = optimize(&mut g);
        assert_eq!((stats.loops_merged, stats.reuses, stats.adds_fused), (0, 1, 1));
        assert_eq!(g.count_kind("add"), 1);
        let surviving = g.node(g.find("r1_add").expect("r1_add survives"));
        assert_eq!(surviving.inputs.len(), 2, "2-operand long-skip merge kept naive");
        let want = build_optimized_graph(&arch, &act, &w);
        assert!(equivalent(&g, &want), "got:\n{g}\nwant:\n{want}");

        // tiednet: every repeated block is a plain identity residual.
        let arch = tiednet(4);
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = optimize(&mut g);
        assert_eq!((stats.loops_merged, stats.reuses, stats.adds_fused), (0, 4, 4));
        let want = build_optimized_graph(&arch, &act, &w);
        assert!(equivalent(&g, &want), "got:\n{g}\nwant:\n{want}");
    }

    #[test]
    fn pipeline_preserves_output_shape() {
        for arch in [resnet8(), resnet20(), skipnet(), longskipnet(), tiednet(2)] {
            let (act, w) = default_exps(&arch);
            let mut g = build_unoptimized_graph(&arch, &act, &w);
            let before = infer_shapes(&g).unwrap()[&crate::graph::Edge::new(g.output().unwrap(), 0)];
            optimize(&mut g);
            let after = infer_shapes(&g).unwrap()[&crate::graph::Edge::new(g.output().unwrap(), 0)];
            assert_eq!(before, after);
        }
    }
}
