//! Add fusion (paper Fig. 13): remove the residual Add node by routing the
//! skip stream into the long branch's second convolution, where it
//! initializes the accumulation register.
//!
//! Pattern (after loop merge / temporal reuse have run, but also matching
//! the raw form):
//!
//! ```text
//!   conv1 ──┐
//!           ├─> add ──> (relu) ──> consumers
//!   skip  ──┘
//! ```
//!
//! becomes
//!
//! ```text
//!   skip ──(SkipInit)──> conv1{relu fused} ──> consumers
//! ```
//!
//! Numerics: the skip value (int8 @ skip_exp) is left-shifted to the
//! accumulator exponent and added before the MAC chain runs — identical,
//! bit for bit, to requantizing conv1's accumulator, adding at the output
//! scale, and re-clipping *only because* the fused form ReLUs/clips once
//! at the very end; the pure-int equivalence of the two dataflows is
//! asserted against the Python oracle (`unoptimized_ref_forward`) through
//! the probe artifacts, and locally by `sim::golden` tests.

use crate::graph::{Graph, InputRole, NodeId, Op};

use super::relu_merge::rewire;

/// Whether `add_id` is a residual merge the fusion pipeline handles: a
/// two-operand Add whose long branch is a single-consumer conv that does
/// not already carry a skip input, and whose skip operand is *block-local*
/// (conv0's input, conv0's forwarding port, or a sibling downsample — the
/// same predicate `hls::config` uses for the Eq. 21 bound).  Multi-input
/// adds, shared long branches and long skips (reaching past the two-conv
/// branch) stay explicit naive dataflow: a fused `SkipInit` stream is
/// sized by Eq. 22, which is only sound for block-local skew — a long
/// skip needs the full-frame FIFO and must keep its Add node.  This
/// mirrors `ResidualSpec::fusable` (`from.is_none()`), and the streaming
/// planner uses this same predicate to accept naive islands outside
/// `naive_add` mode.
pub fn is_fusable_residual(g: &Graph, add_id: NodeId) -> bool {
    let n = g.node(add_id);
    if n.dead || !matches!(n.op, Op::Add { .. }) || n.inputs.len() != 2 {
        return false;
    }
    let long_edge = n.inputs[0].0;
    let conv1 = long_edge.node;
    long_edge.port == 0
        && matches!(g.node(conv1).op, Op::Conv(_))
        && g.consumers(long_edge).len() == 1
        && g.node(conv1).inputs.len() == 1
        && crate::hls::config::skip_is_block_local(g, long_edge, n.inputs[1].0)
}

/// Apply the pass; returns the number of Add nodes fused away.
pub fn add_fusion(g: &mut Graph) -> usize {
    let mut fused = 0;
    let ids: Vec<usize> = g.live().map(|n| n.id).collect();
    for add_id in ids {
        if !is_fusable_residual(g, add_id) {
            continue;
        }
        let (long_edge, skip_edge, add_out_exp) = {
            let n = g.node(add_id);
            let out_exp = match n.op {
                Op::Add { out_exp } => out_exp,
                _ => continue,
            };
            (n.inputs[0].0, n.inputs[1].0, out_exp)
        };
        let conv1 = long_edge.node;

        // Optional trailing ReLU (the paper's blocks always have one).
        let add_consumers = g.consumers(crate::graph::Edge::new(add_id, 0));
        let trailing_relu = match add_consumers.as_slice() {
            [r] if matches!(g.node(*r).op, Op::Relu) => Some(*r),
            _ => None,
        };

        // Fuse: conv1 takes the skip stream as SkipInit, output exponent
        // moves to the add's (they coincide in the builders).
        if let Op::Conv(a) = &mut g.node_mut(conv1).op {
            a.out_exp = add_out_exp;
            // The fused conv requantizes once at the end (the raw 32-bit
            // stream into the Add disappears with the Add itself).
            a.raw_output = false;
            if trailing_relu.is_some() {
                a.relu = true;
            }
        }
        g.node_mut(conv1).inputs.push((skip_edge, InputRole::SkipInit));

        if let Some(r) = trailing_relu {
            rewire(g, crate::graph::Edge::new(r, 0), crate::graph::Edge::new(conv1, 0));
            g.node_mut(r).dead = true;
        }
        rewire(g, crate::graph::Edge::new(add_id, 0), crate::graph::Edge::new(conv1, 0));
        g.node_mut(add_id).dead = true;
        fused += 1;
    }
    fused
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ConvAttrs, Edge};

    fn attrs(c: usize) -> ConvAttrs {
        ConvAttrs {
            cin: c, cout: c, k: 3, stride: 1, pad: 1, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
        }
    }

    #[test]
    fn fuses_add_and_relu() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let c0 = g.add_simple("c0", Op::Conv(attrs(4)), &[Edge::new(i, 0)]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(4)), &[Edge::new(c0, 0)]);
        let add = g.add_simple("add", Op::Add { out_exp: -4 }, &[Edge::new(c1, 0), Edge::new(i, 0)]);
        let r = g.add_simple("relu", Op::Relu, &[Edge::new(add, 0)]);
        g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(r, 0)]);

        assert_eq!(add_fusion(&mut g), 1);
        g.compact();
        assert!(g.validate().is_ok());
        assert_eq!(g.count_kind("add"), 0);
        assert_eq!(g.count_kind("relu"), 0);
        let c1 = g.find("c1").unwrap();
        let n = g.node(c1);
        assert_eq!(n.inputs.len(), 2);
        assert_eq!(n.inputs[1].1, InputRole::SkipInit);
        match &n.op {
            Op::Conv(a) => {
                assert!(a.relu);
                assert_eq!(a.out_exp, -4, "conv1 adopts the add's output exponent");
            }
            _ => unreachable!(),
        }
        let pool = g.find("pool").unwrap();
        assert_eq!(g.node(pool).inputs[0].0.node, c1);
    }

    #[test]
    fn skips_two_operand_long_skip() {
        // A 2-operand merge whose single skip reaches past the two-conv
        // branch (back to the stem's *input*): fusing it would pair an
        // Eq. 22-sized SkipInit FIFO with full-frame skew — the Fig. 14
        // deadlock — so the Add must survive as a naive island.
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let stem = g.add_simple("stem", Op::Conv(attrs(4)), &[Edge::new(i, 0)]);
        let c0 = g.add_simple("c0", Op::Conv(attrs(4)), &[Edge::new(stem, 0)]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(4)), &[Edge::new(c0, 0)]);
        let add =
            g.add_simple("add", Op::Add { out_exp: -4 }, &[Edge::new(c1, 0), Edge::new(i, 0)]);
        g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(add, 0)]);
        assert!(!is_fusable_residual(&g, add));
        assert_eq!(add_fusion(&mut g), 0);
        assert_eq!(g.count_kind("add"), 1);
    }

    #[test]
    fn skips_conv_with_other_consumers() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -7 }, &[]);
        let c1 = g.add_simple("c1", Op::Conv(attrs(4)), &[Edge::new(i, 0)]);
        g.add_simple("add", Op::Add { out_exp: -5 }, &[Edge::new(c1, 0), Edge::new(i, 0)]);
        // Second consumer of conv1's output prevents fusion.
        g.add_simple("c2", Op::Conv(attrs(4)), &[Edge::new(c1, 0)]);
        assert_eq!(add_fusion(&mut g), 0);
    }
}
