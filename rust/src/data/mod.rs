//! Datasets: the deterministic synthetic CIFAR-10 substitute and a loader
//! for the real CIFAR-10 binary format (used automatically if present).

mod cifar;

pub use cifar::{
    load_real_batch, sample, synth_batch, SynthSample, IMG_C, IMG_ELEMS, IMG_H, IMG_W, INPUT_EXP,
    NUM_CLASSES, TEST_SEED, TRAIN_SEED,
};
