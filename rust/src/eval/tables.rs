//! Table 3 (performance) and Table 4 (resources) reproduction.
//!
//! For each (model, board) the paper evaluates, we run the full design
//! flow — graph build → optimization passes → ILP → resource closure →
//! dataflow simulation — and print our row next to the paper's reported
//! row.  Baseline rows come from `sim::baselines` performance models.

use anyhow::Result;

use crate::hls::boards::{Board, KV260, ULTRA96};
use crate::hls::resources::{estimate, fit_to_board, ResourceReport};
use crate::ilp::loads_from_arch;
use crate::models::{arch_by_name, build_optimized_graph, default_exps};
use crate::passes;
use crate::sim::baselines::{addernet_model, finn_model, overlay_model, BaselineRow};
use crate::sim::{build_network, SimOptions};

/// One performance row (Table 3 schema).
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub label: String,
    pub board: String,
    pub bits: u32,
    pub freq_mhz: f64,
    pub fps: f64,
    pub gops: f64,
    pub latency_ms: f64,
    /// Modeled board power (W) and energy per frame (mJ) — see hls::power.
    pub power_w: f64,
    pub mj_per_frame: f64,
    /// Paper's reported value for the same cell, when it exists.
    pub paper_fps: Option<f64>,
    pub paper_gops: Option<f64>,
    pub paper_latency_ms: Option<f64>,
}

/// One resource row (Table 4 schema).
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub label: String,
    pub board: String,
    pub report: ResourceReport,
    pub paper: Option<PaperResources>,
}

#[derive(Debug, Clone, Copy)]
pub struct PaperResources {
    pub kluts: f64,
    pub kffs: f64,
    pub dsps: u64,
    pub bram: f64,
    pub urams: u64,
}

/// Paper Table 3 reference values for *our-design* rows.
fn paper_perf(arch: &str, board: &str) -> Option<(f64, f64, f64, f64)> {
    // (FPS, Gops/s, latency ms, power W)
    match (arch, board) {
        ("resnet20", "KV260") => Some((7_601.0, 616.0, 0.318, 3.61)),
        ("resnet8", "KV260") => Some((30_153.0, 773.0, 0.046, 3.60)),
        ("resnet20", "Ultra96") => Some((3_254.0, 264.0, 0.807, 1.04)),
        ("resnet8", "Ultra96") => Some((12_971.0, 317.0, 0.111, 0.56)),
        _ => None,
    }
}

/// Paper Table 4 reference values for *our-design* rows.
fn paper_resources(arch: &str, board: &str) -> Option<PaperResources> {
    match (arch, board) {
        ("resnet20", "KV260") => Some(PaperResources { kluts: 81.2, kffs: 83.5, dsps: 626, bram: 73.5, urams: 64 }),
        ("resnet8", "KV260") => Some(PaperResources { kluts: 74.6, kffs: 75.7, dsps: 773, bram: 98.0, urams: 63 }),
        ("resnet20", "Ultra96") => Some(PaperResources { kluts: 54.4, kffs: 57.6, dsps: 318, bram: 89.5, urams: 0 }),
        ("resnet8", "Ultra96") => Some(PaperResources { kluts: 46.4, kffs: 45.1, dsps: 360, bram: 54.0, urams: 0 }),
        _ => None,
    }
}

/// Run the full flow for one (arch, board) and produce its Table 3 + 4 rows.
pub fn our_design(arch_name: &str, board: &Board) -> Result<(Table3Row, Table4Row)> {
    let arch = arch_by_name(arch_name).ok_or_else(|| anyhow::anyhow!("unknown arch"))?;
    let (act, w) = default_exps(&arch);
    // Full published flow: unoptimized graph -> optimization passes.
    let mut g = build_optimized_graph(&arch, &act, &w);
    {
        // Rebuild through the pass pipeline to exercise the real flow and
        // assert it lands on the same dataflow.
        let mut from_passes = crate::models::build_unoptimized_graph(&arch, &act, &w);
        passes::optimize(&mut from_passes);
        debug_assert!(passes::equivalent(&g, &from_passes));
        g = from_passes;
    }
    let loads = loads_from_arch(&arch, 2);
    let (_alloc, cfg, report) = fit_to_board(&arch.name, &g, &loads, board, 2)?;

    // Simulate 4 frames for steady-state II + first-frame latency.
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 4, ..Default::default() })?;
    let rep = net.run(4);
    anyhow::ensure!(!rep.deadlocked, "our design must not deadlock");
    let fps = rep.fps(board.clock_mhz);
    let latency_ms = rep.latency_ms(board.clock_mhz);
    let gops = 2.0 * arch.total_macs() as f64 * fps / 1e9;

    let paper = paper_perf(arch_name, board.name);
    let power = crate::hls::power::estimate_power(&report, board, fps, 0.6);
    let t3 = Table3Row {
        label: format!("{arch_name} CNN (our, modeled)"),
        board: board.name.into(),
        bits: 8,
        freq_mhz: board.clock_mhz,
        fps,
        gops,
        latency_ms,
        power_w: power.total_w(),
        mj_per_frame: power.mj_per_frame,
        paper_fps: paper.map(|p| p.0),
        paper_gops: paper.map(|p| p.1),
        paper_latency_ms: paper.map(|p| p.2),
    };
    let t4 = Table4Row {
        label: format!("{arch_name} CNN (our, modeled)"),
        board: board.name.into(),
        report,
        paper: paper_resources(arch_name, board.name),
    };
    Ok((t3, t4))
}

fn baseline_to_row(b: BaselineRow, board: &str, paper: Option<(f64, f64, f64, f64)>) -> Table3Row {
    Table3Row {
        label: b.name,
        board: board.into(),
        bits: b.bits,
        freq_mhz: b.clock_mhz,
        fps: b.fps,
        gops: b.gops,
        latency_ms: b.latency_ms,
        power_w: paper.map(|p| p.3).unwrap_or(f64::NAN),
        mj_per_frame: paper.map(|p| p.3 * b.latency_ms).unwrap_or(f64::NAN),
        paper_fps: paper.map(|p| p.0),
        paper_gops: paper.map(|p| p.1),
        paper_latency_ms: paper.map(|p| p.2),
    }
}

/// All Table 3 rows (our designs + modeled baselines).
pub fn table3() -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    let r20 = arch_by_name("resnet20").unwrap();
    let r8 = arch_by_name("resnet8").unwrap();

    // Paper's baseline rows (references [32] and [30]) — modeled.
    rows.push(baseline_to_row(
        addernet_model(&r20, 200.0, 545),
        "KV260",
        Some((f64::NAN, 214.0, 1.221, 1.07)), // ResNet20 CNN [32]
    ));
    rows.push(baseline_to_row(
        addernet_model(&r20, 200.0, 609),
        "KV260",
        Some((f64::NAN, 317.0, 0.624, 1.52)), // AdderNet [32]
    ));
    let (t3, _) = our_design("resnet20", &KV260)?;
    rows.push(t3);
    rows.push(baseline_to_row(
        finn_model(&r8, 225.0, KV260.luts as u64),
        "KV260",
        Some((13_475.0, 330.0, 0.154, 5.89)), // ResNet8 FINN [30]
    ));
    rows.push(baseline_to_row(
        overlay_model(&r8, 200.0, 2048),
        "KV260",
        Some((4_458.0, 109.0, 1.293, 6.42)), // ResNet8 Vitis AI [30]
    ));
    let (t3, _) = our_design("resnet8", &KV260)?;
    rows.push(t3);
    let (t3, _) = our_design("resnet20", &ULTRA96)?;
    rows.push(t3);
    let (t3, _) = our_design("resnet8", &ULTRA96)?;
    rows.push(t3);
    Ok(rows)
}

/// All Table 4 rows (our designs).
pub fn table4() -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for arch in ["resnet20", "resnet8"] {
        for board in [&KV260, &ULTRA96] {
            let (_, t4) = our_design(arch, board)?;
            rows.push(t4);
        }
    }
    Ok(rows)
}

/// Pretty-print Table 3 with paper references.
pub fn print_table3(rows: &[Table3Row]) {
    println!("== Table 3: performance (modeled) vs paper ==");
    println!(
        "{:<30} {:<8} {:>4} {:>6} {:>10} {:>9} {:>9} {:>7} {:>8}   {:>10} {:>9} {:>9}",
        "Model", "Board", "Bit", "MHz", "FPS", "Gops/s", "Lat(ms)", "P(W)", "mJ/frm", "pFPS", "pGops", "pLat"
    );
    for r in rows {
        println!(
            "{:<30} {:<8} {:>4} {:>6.0} {:>10.0} {:>9.0} {:>9.3} {:>7.2} {:>8.3}   {:>10} {:>9} {:>9}",
            r.label,
            r.board,
            r.bits,
            r.freq_mhz,
            r.fps,
            r.gops,
            r.latency_ms,
            r.power_w,
            r.mj_per_frame,
            r.paper_fps.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into()),
            r.paper_gops.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into()),
            r.paper_latency_ms.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Pretty-print Table 4 with paper references.
pub fn print_table4(rows: &[Table4Row]) {
    println!("== Table 4: resources (modeled) vs paper ==");
    println!(
        "{:<30} {:<8} {:>8} {:>8} {:>6} {:>6} {:>6}   {:>8} {:>8} {:>6} {:>6} {:>6}",
        "Model", "Board", "kLUT", "kFF", "DSP", "BRAM", "URAM", "pkLUT", "pkFF", "pDSP", "pBRAM", "pURAM"
    );
    for r in rows {
        let rep = &r.report;
        let p = r.paper;
        println!(
            "{:<30} {:<8} {:>8.1} {:>8.1} {:>6} {:>6} {:>6}   {:>8} {:>8} {:>6} {:>6} {:>6}",
            r.label,
            r.board,
            rep.luts as f64 / 1e3,
            rep.ffs as f64 / 1e3,
            rep.dsps,
            rep.bram36,
            rep.urams,
            p.map(|p| format!("{:.1}", p.kluts)).unwrap_or_else(|| "-".into()),
            p.map(|p| format!("{:.1}", p.kffs)).unwrap_or_else(|| "-".into()),
            p.map(|p| format!("{}", p.dsps)).unwrap_or_else(|| "-".into()),
            p.map(|p| format!("{:.1}", p.bram)).unwrap_or_else(|| "-".into()),
            p.map(|p| format!("{}", p.urams)).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Convenience: full estimate without the closure loop (for ablations).
pub fn estimate_at_budget(arch_name: &str, board: &Board, budget: u64, ow_par: usize) -> Result<(f64, ResourceReport)> {
    let arch = arch_by_name(arch_name).ok_or_else(|| anyhow::anyhow!("unknown arch"))?;
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, ow_par);
    let alloc = crate::ilp::solve(&loads, budget)
        .ok_or_else(|| anyhow::anyhow!("infeasible at {budget}"))?;
    let cfg = crate::hls::config::configure(&arch.name, &g, &alloc, board, ow_par)?;
    Ok((cfg.fps(), estimate(&cfg)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_rows_land_in_paper_band() {
        for (arch, board, paper_fps) in [
            ("resnet8", &ULTRA96, 12_971.0),
            ("resnet20", &ULTRA96, 3_254.0),
            ("resnet8", &KV260, 30_153.0),
            ("resnet20", &KV260, 7_601.0),
        ] {
            let (t3, _) = our_design(arch, board).unwrap();
            let ratio = t3.fps / paper_fps;
            assert!(
                (0.4..=2.5).contains(&ratio),
                "{arch}@{}: fps {} vs paper {paper_fps} (x{ratio:.2})",
                board.name,
                t3.fps
            );
        }
    }

    #[test]
    fn resnet8_beats_resnet20_by_ops_ratio() {
        let (a, _) = our_design("resnet8", &KV260).unwrap();
        let (b, _) = our_design("resnet20", &KV260).unwrap();
        let r = a.fps / b.fps;
        // Paper: 30153/7601 = 3.97; ops ratio ~3.2.
        assert!((2.0..=6.0).contains(&r), "fps ratio {r}");
    }

    #[test]
    fn kv260_beats_ultra96() {
        let (a, _) = our_design("resnet8", &KV260).unwrap();
        let (b, _) = our_design("resnet8", &ULTRA96).unwrap();
        // Paper: 30153/12971 = 2.3.
        let r = a.fps / b.fps;
        assert!((1.3..=4.0).contains(&r), "fps ratio {r}");
    }
}
