//! Experiment harnesses: regenerate every table and figure of the paper's
//! evaluation section (paper-vs-measured, shape comparison).

pub mod figures;
pub mod tables;

pub use tables::{table3, table4, Table3Row, Table4Row};
