//! Figure reproductions: the data series behind the paper's figures.
//!
//! * Fig. 5  — DSP packing pipelines (chain structure, MACs/DSP);
//! * Fig. 7/9 — window-buffer partitioning (slice sizes per ow_par);
//! * Fig. 11/14 + Eq. 23 — skip-connection buffering, naive vs optimized,
//!   per residual block of each network;
//! * Alg. 1 — throughput vs DSP-budget sweep.

use crate::hls::packing::{chain_plan, macs_per_cycle};
use crate::hls::window::{
    skip_buffer_naive, skip_buffer_optimized, slice_plan,
};
use crate::ilp::{loads_from_arch, solve};
use crate::models::{arch_by_name, ArchSpec};

/// Eq. 23 series: per two-conv residual segment, (name, B_sc naive,
/// B_sc optimized, R_sc).  Residuals with deeper bodies fall outside the
/// paper's two-conv derivation and are skipped.
pub fn skip_buffering_series(arch: &ArchSpec) -> Vec<(String, usize, usize, f64)> {
    arch.residuals()
        .filter(|r| r.body.len() == 2)
        .map(|r| {
            let c0 = &r.body[0];
            let c1 = &r.body[1];
            let naive = skip_buffer_naive(c0.k, c0.k, c0.in_w, c0.cin, c1.k, c1.k);
            let opt = skip_buffer_optimized(c1.k, c1.k, c1.in_w, c1.cin);
            (r.name.clone(), naive, opt, opt as f64 / naive as f64)
        })
        .collect()
}

/// Fig. 5 data: for a filter size, the packed pipeline structure.
pub struct PackingFigure {
    pub taps: usize,
    pub chains: Vec<usize>,
    pub extra_adders: usize,
    pub macs_per_cycle_packed: usize,
    pub macs_per_cycle_unpacked: usize,
    pub dsps: usize,
}

pub fn packing_figure(taps: usize, och_par: usize) -> PackingFigure {
    let plan = chain_plan(taps);
    PackingFigure {
        taps,
        chains: plan.chains.clone(),
        extra_adders: plan.extra_adders * och_par,
        macs_per_cycle_packed: macs_per_cycle(och_par, taps, 2),
        macs_per_cycle_unpacked: macs_per_cycle(och_par, taps, 1),
        dsps: och_par * taps,
    }
}

/// Fig. 7/9 data: slice sizes of a window buffer.  Errors (typed) when
/// the widened window cannot fit the row — see `hls::window::WindowError`.
pub fn window_figure(
    k: usize,
    iw: usize,
    ich: usize,
    ow_par: usize,
) -> Result<Vec<usize>, crate::hls::window::WindowError> {
    Ok(slice_plan(k, k, iw, ich, ow_par)?.sizes)
}

/// Alg. 1 sweep: (budget, fps_per_mhz, dsps_used) for a range of budgets.
pub fn ilp_sweep(arch_name: &str, budgets: &[u64], ow_par: usize) -> Vec<(u64, f64, u64)> {
    let arch = arch_by_name(arch_name).expect("arch");
    let loads = loads_from_arch(&arch, ow_par);
    budgets
        .iter()
        .filter_map(|&b| {
            solve(&loads, b).map(|a| (b, 1e6 / a.cycles_per_frame as f64, a.dsps_used))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet20, resnet8};

    #[test]
    fn eq23_holds_for_every_block_of_both_nets() {
        for arch in [resnet8(), resnet20()] {
            for (name, naive, opt, r) in skip_buffering_series(&arch) {
                // Paper Eq. 23 reports R_sc = 0.5 (exactly 0.511 for the
                // 32-wide blocks, up to 0.522 at the 8-wide final stage).
                assert!(
                    (0.47..=0.53).contains(&r),
                    "{}/{name}: R_sc = {r} ({opt}/{naive})",
                    arch.name
                );
            }
        }
    }

    #[test]
    fn packing_doubles_throughput() {
        let f = packing_figure(9, 8);
        assert_eq!(f.chains, vec![7, 2]);
        assert_eq!(f.macs_per_cycle_packed, 2 * f.macs_per_cycle_unpacked);
        assert_eq!(f.dsps, 72);
    }

    #[test]
    fn ilp_sweep_is_monotone() {
        let pts = ilp_sweep("resnet8", &[64, 128, 256, 512, 1024], 2);
        assert!(pts.len() >= 4);
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1, "throughput decreased with budget");
        }
    }
}
