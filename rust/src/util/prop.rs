//! Tiny property-based testing helper (proptest is not available offline).
//!
//! `forall` runs a closure over `n` seeded random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this environment)
//! use resnet_hls::util::prop::forall;
//! forall("add commutes", 100, |rng| {
//!     let a = rng.range_i64(-1000, 1000);
//!     let b = rng.range_i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Lcg64;

/// Run `body` for `n` cases with independent deterministic seeds.
///
/// Panics (preserving the inner assertion message) with the failing case
/// index and seed on the first failure.
pub fn forall<F>(name: &str, n: u64, body: F)
where
    F: Fn(&mut Lcg64) + std::panic::RefUnwindSafe,
{
    for case in 0..n {
        let seed = 0x9e3779b97f4a7c15u64.wrapping_mul(case + 1);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Lcg64::new(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Like `forall` but the body returns `Result`, for use with `?`-heavy code.
pub fn forall_res<F, E>(name: &str, n: u64, body: F)
where
    F: Fn(&mut Lcg64) -> Result<(), E> + std::panic::RefUnwindSafe,
    E: std::fmt::Debug,
{
    forall(name, n, |rng| {
        if let Err(e) = body(rng) {
            panic!("{e:?}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("identity", 50, |rng| {
            let x = rng.next_u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_seed() {
        forall("always fails", 5, |_| panic!("boom"));
    }
}
