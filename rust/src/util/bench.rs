//! Micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets are plain binaries (`harness = false`) built on
//! this module: warmup, fixed-duration sampling, and median / p10 / p90
//! reporting.  Results can be appended to a machine-readable log so the
//! performance pass (EXPERIMENTS.md §Perf) can diff before/after.

use std::time::{Duration, Instant};

/// One measured statistic set, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    /// Optional throughput unit count per iteration (e.g. frames, MACs).
    pub items_per_iter: f64,
}

impl Stats {
    /// Items per second at the median (0 if no item count set).
    pub fn items_per_sec(&self) -> f64 {
        if self.items_per_iter == 0.0 {
            0.0
        } else {
            self.items_per_iter / (self.median_ns * 1e-9)
        }
    }
}

/// Simple fixed-budget bencher.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Respect a quick mode for CI-ish runs: REPRO_BENCH_QUICK=1.
        let quick = std::env::var("REPRO_BENCH_QUICK").ok().as_deref() == Some("1");
        Bencher {
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            min_samples: 5,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Measure `f`, which performs one logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        self.bench_items(name, 0.0, &mut f)
    }

    /// Measure with a throughput unit (items processed per iteration).
    pub fn bench_items<F: FnMut()>(&mut self, name: &str, items: f64, f: &mut F) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.budget || samples_ns.len() < self.min_samples {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() > 100_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let stats = Stats {
            name: name.to_string(),
            samples: samples_ns.len(),
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
            items_per_iter: items,
        };
        self.report(&stats);
        self.results.push(stats.clone());
        stats
    }

    fn report(&self, s: &Stats) {
        let (val, unit) = human_ns(s.median_ns);
        if s.items_per_iter > 0.0 {
            println!(
                "{:<44} {:>9.2} {}/iter   [p10 {:.2}, p90 {:.2}]   {:>12.1} items/s   ({} samples)",
                s.name,
                val,
                unit,
                human_ns(s.p10_ns).0,
                human_ns(s.p90_ns).0,
                s.items_per_sec(),
                s.samples
            );
        } else {
            println!(
                "{:<44} {:>9.2} {}/iter   [p10 {:.2}, p90 {:.2}]   ({} samples)",
                s.name,
                val,
                unit,
                human_ns(s.p10_ns).0,
                human_ns(s.p90_ns).0,
                s.samples
            );
        }
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

fn human_ns(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "us")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s ")
    }
}

/// Prevent the optimizer from eliding a computed value (std black_box is
/// stable but this keeps call sites uniform).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("REPRO_BENCH_QUICK", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        let s = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.median_ns > 0.0);
        assert!(s.samples >= 5);
    }
}
