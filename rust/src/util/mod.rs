//! Small self-contained utilities.
//!
//! The offline environment ships only the `xla` crate and its transitive
//! dependencies, so the conveniences a project like this would normally pull
//! from crates.io (serde_json, clap, criterion, proptest, rand) are
//! implemented here at the scale this repo needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use bench::Bencher;
pub use json::Json;
pub use rng::Lcg64;
