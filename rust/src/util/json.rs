//! Minimal JSON parser + writer (serde_json is not available offline).
//!
//! Supports the full JSON grammar minus exotic number formats; numbers are
//! kept as `f64` with an `i64` fast path, which covers everything the
//! `artifacts/manifest.json` contract uses.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("models")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Path access with '/' separators: `j.at("probe/input")`.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>().map(Json::Float).map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>().map(Json::Int).map_err(|_| self.err("bad number"))
        }
    }
}

/// Serialize a value (stable key order — `Object` is a BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Float(x) => write!(f, "{x}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Object(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": -7}"#).unwrap();
        assert_eq!(j.at("c").unwrap().as_i64(), Some(-7));
        assert_eq!(j.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_array().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"neg":-3}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo é"));
    }
}
