//! Deterministic 64-bit LCG (MMIX constants).
//!
//! This is the *specified* noise source of the synthetic CIFAR-10 dataset —
//! `python/compile/data.py` implements the identical recurrence, and the
//! probe batch exported by `aot.py` asserts cross-language bit-equality.
//! It also backs the in-repo property-testing helper (`util::prop`).

/// 64-bit linear congruential generator: `s' = s * A + C (mod 2^64)`.
#[derive(Debug, Clone)]
pub struct Lcg64 {
    state: u64,
}

/// Knuth's MMIX multiplier.
pub const LCG_A: u64 = 6364136223846793005;
/// MMIX increment.
pub const LCG_C: u64 = 1442695040888963407;

impl Lcg64 {
    pub fn new(seed: u64) -> Self {
        Lcg64 { state: seed }
    }

    /// Advance one step and return the new raw state.
    #[inline]
    pub fn next_state(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(LCG_A).wrapping_add(LCG_C);
        self.state
    }

    /// The dataset's byte extraction: bits [33, 41) of the state.
    #[inline]
    pub fn next_byte(&mut self) -> u8 {
        ((self.next_state() >> 33) & 0xff) as u8
    }

    /// Uniform u64 (for property testing; mixes two steps for high bits).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let hi = self.next_state() >> 32;
        let lo = self.next_state() >> 32;
        (hi << 32) | lo
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.below(span) as i64)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Lcg64::new(42);
        let mut b = Lcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Lcg64::new(7);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 9);
            assert!((-5..=9).contains(&v));
        }
    }

    #[test]
    fn byte_extraction_matches_spec() {
        // First steps from seed 0 — pinned so the Python spec can't drift.
        let mut r = Lcg64::new(0);
        let s1 = r.next_state();
        assert_eq!(s1, LCG_C);
        assert_eq!(((s1 >> 33) & 0xff) as u8, ((1442695040888963407u64 >> 33) & 0xff) as u8);
    }
}
