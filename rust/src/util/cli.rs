//! Minimal command-line parsing (clap is not available offline).
//!
//! Supports `prog <subcommand> [--flag] [--key value] [positional...]`,
//! which covers the `repro` binary's surface.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, `--key value` options, `--flag` switches,
/// and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Option/flag spec for a subcommand: (name, takes_value, help).
pub type OptSpec = (&'static str, bool, &'static str);

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]); `value_opts` lists the
    /// option names that consume a value.
    pub fn parse(raw: impl Iterator<Item = String>, value_opts: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = raw.peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if value_opts.contains(&name) {
                    let v = iter.next().unwrap_or_default();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> std::vec::IntoIter<String> {
        s.iter().map(|x| x.to_string()).collect::<Vec<_>>().into_iter()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            v(&["simulate", "--model", "resnet8", "--verbose", "extra"]),
            &["model"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("model"), Some("resnet8"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(v(&["x", "--n", "12", "--r", "2.5"]), &["n", "r"]);
        assert_eq!(a.opt_usize("n", 0), 12);
        assert_eq!(a.opt_f64("r", 0.0), 2.5);
        assert_eq!(a.opt_usize("missing", 7), 7);
    }
}
