//! Scalar quantization arithmetic — the contract functions.

use super::{INT8_MAX, INT8_MIN};

/// Requantizing shift: `shift > 0` is an arithmetic right shift with
/// round-half-up (`floor((acc + 2^(s-1)) / 2^s)`); `shift <= 0` is an exact
/// left shift.  Identical to `quantize.round_shift` on the Python side —
/// i32 `>>` is an arithmetic (floor) shift in both languages.
#[inline]
pub fn round_shift(acc: i32, shift: i32) -> i32 {
    if shift <= 0 {
        acc.wrapping_shl((-shift) as u32)
    } else {
        let half = 1i32 << (shift - 1);
        acc.wrapping_add(half) >> shift
    }
}

/// [`round_shift`] widened to `i64` for paths that align two operands
/// before summing (the residual Add at the finer exponent): the aligned
/// sum of a raw int32 accumulator and a shifted int8 stream can exceed
/// `i32`, so the shift-and-round must happen at 64 bits.  Shift amounts
/// are clamped to the type width instead of panicking — a malformed
/// exponent table yields a clipped value, not a crash.
#[inline]
pub fn round_shift_i64(acc: i64, shift: i32) -> i64 {
    if shift <= 0 {
        acc.wrapping_shl((-shift).min(63) as u32)
    } else if shift >= 64 {
        // floor((acc + 2^(s-1)) / 2^s) -> 0 for any i64 once s >= 64.
        0
    } else {
        let half = 1i64 << (shift - 1);
        acc.wrapping_add(half) >> shift
    }
}

/// Clip to the signed int8 grid (paper Eq. 1's clip with Eqs. 2–3 bounds).
#[inline]
pub fn clip_i8(x: i32) -> i32 {
    x.clamp(INT8_MIN, INT8_MAX)
}

/// [`clip_i8`] for a 64-bit aligned value (see [`round_shift_i64`]).
#[inline]
pub fn clip_i8_wide(x: i64) -> i32 {
    x.clamp(INT8_MIN as i64, INT8_MAX as i64) as i32
}

/// Full requantization of an int32 accumulator at `acc_exp` down to an int8
/// activation at `out_exp`, with the fused ReLU applied on the accumulator
/// (the generated HLS applies ReLU to the 32-bit register before shifting).
#[inline]
pub fn requantize(acc: i32, acc_exp: i32, out_exp: i32, relu: bool) -> i32 {
    let acc = if relu { acc.max(0) } else { acc };
    clip_i8(round_shift(acc, out_exp - acc_exp))
}

/// Align an int8 skip-connection value at `skip_exp` to the accumulator
/// exponent (paper Fig. 13: the skip value initializes the accumulation
/// register).  `skip_exp >= acc_exp` always holds for these nets.
#[inline]
pub fn align_skip(skip: i32, skip_exp: i32, acc_exp: i32) -> i32 {
    let shift = skip_exp - acc_exp;
    debug_assert!(shift >= 0, "skip exp {skip_exp} below acc exp {acc_exp}");
    skip << shift
}

/// Tightest power-of-two exponent covering `max_abs` on `bits` bits —
/// mirrors `quantize.pow2_exponent` (used only by tooling; the inference
/// path receives exponents from the manifest).
pub fn pow2_exponent(max_abs: f64, bits: u32) -> i32 {
    let limit = ((1u32 << (bits - 1)) - 1) as f64;
    if max_abs <= 0.0 || !max_abs.is_finite() {
        return -((bits - 1) as i32);
    }
    (max_abs / limit).log2().ceil() as i32
}

/// Quantize a float to the int grid at `exp` (training/tooling only).
pub fn quantize_pow2(x: f64, exp: i32, bits: u32) -> i32 {
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    let scaled = (x * (2f64).powi(-exp)).round() as i64;
    scaled.clamp(lo, hi) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn round_shift_matches_floor_semantics() {
        // floor((acc + half) / 2^s) including negatives.
        assert_eq!(round_shift(10, 2), 3); // (10+2)>>2 = 3
        assert_eq!(round_shift(-10, 2), -2); // (-10+2)>>2 = floor(-8/4) = -2
        assert_eq!(round_shift(7, 0), 7);
        assert_eq!(round_shift(7, -2), 28);
        assert_eq!(round_shift(-1, 1), 0); // (-1+1)>>1
    }

    #[test]
    fn round_shift_is_floor_div_property() {
        forall("round_shift == floor((x+half)/2^s)", 2000, |rng| {
            let x = rng.range_i64(-(1 << 28), 1 << 28) as i32;
            let s = rng.range_i64(1, 20) as i32;
            let half = 1i64 << (s - 1);
            let expect = ((x as i64 + half).div_euclid(1i64 << s)) as i32;
            assert_eq!(round_shift(x, s), expect, "x={x} s={s}");
        });
    }

    #[test]
    fn round_shift_i64_agrees_with_i32_in_range() {
        forall("round_shift_i64 == round_shift on i32 range", 2000, |rng| {
            let x = rng.range_i64(-(1 << 30), 1 << 30) as i32;
            let s = rng.range_i64(-3, 20) as i32;
            assert_eq!(round_shift_i64(x as i64, s), round_shift(x, s) as i64, "x={x} s={s}");
        });
        // Beyond-i32 alignment sums round without wrapping.
        assert_eq!(round_shift_i64(i32::MAX as i64 + 256, 8), (1 << 23) + 1);
        // Degenerate shift amounts clamp instead of panicking.
        assert_eq!(round_shift_i64(1 << 40, 64), 0);
        assert_eq!(clip_i8_wide(i64::MAX), 127);
        assert_eq!(clip_i8_wide(i64::MIN), -128);
    }

    #[test]
    fn requantize_clips_and_relus() {
        assert_eq!(requantize(1 << 20, 0, 8, false), 127);
        assert_eq!(requantize(-(1 << 20), 0, 8, false), -128);
        assert_eq!(requantize(-(1 << 20), 0, 8, true), 0);
        assert_eq!(requantize(256, 0, 2, false), 64);
    }

    #[test]
    fn align_skip_exact() {
        assert_eq!(align_skip(-5, -6, -14), -5 << 8);
        assert_eq!(align_skip(127, -5, -13), 127 << 8);
    }

    #[test]
    fn pow2_exponent_tight() {
        // max 127 on 8 bits -> exponent 0.
        assert_eq!(pow2_exponent(127.0, 8), 0);
        // max 1.0 -> 1.0 <= 127 * 2^e -> e = -6 (2^-7*127 = 0.99 < 1).
        assert_eq!(pow2_exponent(1.0, 8), -6);
        forall("pow2 exponent covers max", 500, |rng| {
            let m = rng.next_f64() * 100.0 + 1e-6;
            let e = pow2_exponent(m, 8);
            assert!(127.0 * (2f64).powi(e) >= m * 0.999999);
            assert!(127.0 * (2f64).powi(e - 1) < m);
        });
    }
}
