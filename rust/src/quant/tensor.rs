//! Integer tensor container used by the golden model and the simulator.
//!
//! NHWC layout (depth-first / channel-last), matching the accelerator's
//! streaming order (paper Section III-F: activations are produced in
//! depth-first order) and the Python side's array layout.

use std::fmt;

/// 4-D shape (N, H, W, C).  Lower-rank tensors set trailing dims to 1 in
/// the natural way (e.g. logits are (N, 1, 1, C)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape4 {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape4 {
    pub fn new(n: usize, h: usize, w: usize, c: usize) -> Self {
        Shape4 { n, h, w, c }
    }

    pub fn elems(&self) -> usize {
        self.n * self.h * self.w * self.c
    }
}

impl fmt::Display for Shape4 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{},{},{})", self.n, self.h, self.w, self.c)
    }
}

/// Integer tensor with a power-of-two scale: `real = data * 2^exp`.
///
/// Payload is `i32` regardless of the logical width (int8 activations,
/// int16 biases, int32 accumulators) — the logical grid is enforced at the
/// producing operation, exactly as in the Python contract.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub shape: Shape4,
    pub exp: i32,
    pub data: Vec<i32>,
}

impl QTensor {
    pub fn zeros(shape: Shape4, exp: i32) -> Self {
        QTensor { shape, exp, data: vec![0; shape.elems()] }
    }

    pub fn from_vec(shape: Shape4, exp: i32, data: Vec<i32>) -> Self {
        assert_eq!(shape.elems(), data.len(), "shape {shape} vs {} elems", data.len());
        QTensor { shape, exp, data }
    }

    /// NHWC linear index.
    #[inline]
    pub fn idx(&self, n: usize, y: usize, x: usize, c: usize) -> usize {
        ((n * self.shape.h + y) * self.shape.w + x) * self.shape.c + c
    }

    #[inline]
    pub fn at(&self, n: usize, y: usize, x: usize, c: usize) -> i32 {
        self.data[self.idx(n, y, x, c)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, y: usize, x: usize, c: usize, v: i32) {
        let i = self.idx(n, y, x, c);
        self.data[i] = v;
    }

    /// Dequantized view (tooling/debug only — the inference path is integer).
    pub fn dequantize(&self) -> Vec<f32> {
        let s = (2f32).powi(self.exp);
        self.data.iter().map(|&q| q as f32 * s).collect()
    }

    /// Assert every element is on the signed `bits`-bit grid.
    pub fn assert_bits(&self, bits: u32) {
        let lo = -(1i32 << (bits - 1));
        let hi = (1i32 << (bits - 1)) - 1;
        for (i, &v) in self.data.iter().enumerate() {
            assert!(v >= lo && v <= hi, "elem {i} = {v} outside int{bits}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_nhwc() {
        let mut t = QTensor::zeros(Shape4::new(2, 3, 4, 5), -6);
        t.set(1, 2, 3, 4, 42);
        // last element of the buffer
        assert_eq!(t.data[2 * 3 * 4 * 5 - 1], 42);
        assert_eq!(t.at(1, 2, 3, 4), 42);
    }

    #[test]
    fn dequantize_applies_scale() {
        let t = QTensor::from_vec(Shape4::new(1, 1, 1, 2), -1, vec![3, -4]);
        assert_eq!(t.dequantize(), vec![1.5, -2.0]);
    }

    #[test]
    #[should_panic]
    fn bits_assertion_fires() {
        let t = QTensor::from_vec(Shape4::new(1, 1, 1, 1), 0, vec![300]);
        t.assert_bits(8);
    }
}
