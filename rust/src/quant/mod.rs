//! Power-of-two quantization (paper Section III-A, Eqs. 1–3).
//!
//! Bit-exact mirror of `python/compile/kernels/quantize.py` — the shared
//! arithmetic contract that lets the Rust golden model, the dataflow
//! simulator, and the PJRT-executed HLO agree to the last bit.
//!
//! A quantized tensor is an integer payload plus a power-of-two exponent:
//! `real = q * 2^exp`.  Weights/activations are int8, biases int16 (stored
//! at the accumulator exponent), accumulators int32 (Eq. 5 shows 30 bits
//! suffice for the worst ResNet8/20 layer; 32 is used for the same reasons
//! as the paper — no overflow plus native-width registers).

mod ops;
mod tensor;

pub use ops::*;
pub use tensor::{QTensor, Shape4};

/// int8 clipping bounds (paper Eq. 2/3, signed case).
pub const INT8_MIN: i32 = -128;
pub const INT8_MAX: i32 = 127;
/// int16 bias bounds.
pub const INT16_MIN: i32 = -(1 << 15);
pub const INT16_MAX: i32 = (1 << 15) - 1;

/// Accumulator bit-width needed for a conv layer (paper Eq. 5):
/// `ceil(log2(N_acc)) + 2*bw`.
pub fn acc_bits(och: usize, ich: usize, fh: usize, fw: usize, bw: u32) -> u32 {
    let n_acc = (och * ich * fh * fw) as u64;
    (64 - n_acc.leading_zeros()).max(1) + 2 * bw
    // NOTE: `64 - leading_zeros` is ceil(log2(n)) for n not a power of two
    // and log2(n)+1 for exact powers — the paper's Eq. 6/7 example
    // (N=9216 -> 14 bits) uses ceil(log2); both give <= 32 for these nets,
    // and the +1 on powers of two is the safe direction.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_eq7_worst_case_fits_i32() {
        // Resnet8/20 worst case: 32*32*3*3 = 9216 accumulations (Eq. 6).
        let bits = acc_bits(32, 32, 3, 3, 8);
        assert!(bits <= 32, "paper chooses 32-bit accumulators; got {bits}");
        assert!(bits >= 30, "Eq. 7 computes 30 bits; got {bits}");
    }
}
