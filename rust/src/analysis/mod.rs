//! Static pipeline verification: prove a planned pipeline safe **before
//! any thread spawns**.
//!
//! The paper's central hazard is structural, not numeric: an undersized
//! skip FIFO deadlocks the free-running dataflow (Fig. 14).  Until now
//! the repo discovered that at *runtime*, as a typed
//! [`StreamError::Stalled`](crate::stream::StreamError) after the stage
//! threads were already spinning — yet Eqs. 16/17/21/22 contain
//! everything needed to prove safety from the plan alone.  This module
//! is that proof, split into three passes:
//!
//! * [`deadlock`] — worst-case token accounting over the blueprint's
//!   FIFO/skip/merge graph: every declared skip depth must meet its
//!   Eq. 21 (naive receptive-field) or Eq. 22 (fused window-span) lower
//!   bound.  An undersized edge is reported by name together with the
//!   minimum safe depth, turning the Fig. 14 deadlock into a *static*
//!   diagnostic (the runtime `Stalled` watchdog stays as
//!   defense-in-depth).
//! * [`ranges`] — interval analysis over the quantized datapath:
//!   worst-case i32 accumulator magnitudes per layer from the actual i8
//!   weight magnitudes, 16-bit biases and the skip-add widening path
//!   (falling back to sound dtype bounds when a layer has no weights,
//!   e.g. an imported QONNX graph).
//! * [`feasibility`] — Eq. 16/17 window/shape cross-check: the slice
//!   spans are re-derived from the graph and compared against what
//!   `hls::config::configure` stored, so planner and executor can never
//!   disagree silently.
//!
//! Entry points: [`verify`] runs all three passes and returns the full
//! [`AnalysisReport`] (the `repro verify` subcommand renders it as text
//! or JSON); [`preflight`] runs the structural passes (deadlock +
//! feasibility) and is invoked by `stream::stage::plan_pipeline`, so
//! `StreamPool`/`StreamBackend` refuse a provably-deadlocking
//! configuration with a typed [`AnalysisError`] before a single stage
//! thread exists.  `StreamConfig::static_checks` is the escape hatch
//! the deadlock-regression tests use to reach the runtime watchdog.

// Verifier results feed serving preflight; diagnostics must come back as
// typed values, never a panic.  `clippy.toml` disallows Option/Result
// unwrap+expect; test modules opt out locally.
#![deny(clippy::disallowed_methods)]

pub mod deadlock;
pub mod feasibility;
pub mod ranges;

use std::collections::BTreeMap;
use std::fmt;

use anyhow::Result;

use crate::graph::Graph;
use crate::hls::config::AcceleratorConfig;
use crate::models::ModelWeights;
use crate::stream::StreamConfig;
use crate::util::Json;

/// Severity of one finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// A check that ran and passed (kept in the report so "verified"
    /// is distinguishable from "never looked").
    Info,
    /// Suspicious but not provably unsafe (e.g. planner/analyzer
    /// disagreement, thin accumulator headroom).
    Warning,
    /// Provably unsafe: the configuration must be rejected.
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One finding from a verification pass.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable machine-readable code (catalogued in the README), e.g.
    /// `fifo.undersized` or `range.overflow`.
    pub code: &'static str,
    pub severity: Severity,
    /// The FIFO edge or layer the finding is about (e.g. `s0b0_add.skip`).
    pub subject: String,
    /// Human-readable explanation.
    pub message: String,
    /// The value the check measured (declared FIFO depth, worst-case
    /// accumulator magnitude, ...).
    pub measured: Option<i64>,
    /// The bound it was compared against.
    pub bound: Option<i64>,
    /// For undersized FIFOs: the minimum depth that is provably safe.
    pub min_safe_depth: Option<usize>,
}

impl Diagnostic {
    pub fn new(
        severity: Severity,
        code: &'static str,
        subject: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity,
            subject: subject.into(),
            message: message.into(),
            measured: None,
            bound: None,
            min_safe_depth: None,
        }
    }

    /// Attach the measured-vs-bound pair.
    pub fn with_values(mut self, measured: i64, bound: i64) -> Diagnostic {
        self.measured = Some(measured);
        self.bound = Some(bound);
        self
    }

    /// Attach the minimum safe FIFO depth.
    pub fn with_min_safe_depth(mut self, depth: usize) -> Diagnostic {
        self.min_safe_depth = Some(depth);
        self
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("code".into(), Json::Str(self.code.into()));
        o.insert("severity".into(), Json::Str(self.severity.as_str().into()));
        o.insert("subject".into(), Json::Str(self.subject.clone()));
        o.insert("message".into(), Json::Str(self.message.clone()));
        if let Some(m) = self.measured {
            o.insert("measured".into(), Json::Int(m));
        }
        if let Some(b) = self.bound {
            o.insert("bound".into(), Json::Int(b));
        }
        if let Some(d) = self.min_safe_depth {
            o.insert("min_safe_depth".into(), Json::Int(d as i64));
        }
        Json::Object(o)
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{:<7}] {:<24} {}: {}",
            self.severity, self.code, self.subject, self.message
        )?;
        if let Some(d) = self.min_safe_depth {
            write!(f, " (min safe depth {d})")?;
        }
        Ok(())
    }
}

/// The combined result of the verification passes.
#[derive(Debug, Clone, Default)]
pub struct AnalysisReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    /// True when no Error-severity diagnostic is present.
    pub fn ok(&self) -> bool {
        self.count(Severity::Error) == 0
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    /// The Error-severity findings.
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    /// Find a diagnostic by code and subject (test convenience).
    pub fn find(&self, code: &str, subject: &str) -> Option<&Diagnostic> {
        self.diagnostics.iter().find(|d| d.code == code && d.subject == subject)
    }

    /// `Err(AnalysisError)` carrying the Error-severity findings when
    /// the report rejects the configuration.
    pub fn into_result(self) -> Result<AnalysisReport, AnalysisError> {
        if self.ok() {
            Ok(self)
        } else {
            Err(AnalysisError {
                diagnostics: self
                    .diagnostics
                    .into_iter()
                    .filter(|d| d.severity == Severity::Error)
                    .collect(),
            })
        }
    }

    /// The JSON document `repro verify --json` emits: stable key order,
    /// diagnostics in pass order.
    pub fn to_json(&self) -> Json {
        let mut counts = BTreeMap::new();
        for s in [Severity::Info, Severity::Warning, Severity::Error] {
            counts.insert(s.as_str().to_string(), Json::Int(self.count(s) as i64));
        }
        let mut o = BTreeMap::new();
        o.insert(
            "status".into(),
            Json::Str(if self.ok() { "ok" } else { "rejected" }.into()),
        );
        o.insert("counts".into(), Json::Object(counts));
        o.insert(
            "diagnostics".into(),
            Json::Array(self.diagnostics.iter().map(|d| d.to_json()).collect()),
        );
        Json::Object(o)
    }
}

impl fmt::Display for AnalysisReport {
    /// Errors first, then warnings, then the passed checks, closed by
    /// a one-line verdict.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for sev in [Severity::Error, Severity::Warning, Severity::Info] {
            for d in self.diagnostics.iter().filter(|d| d.severity == sev) {
                writeln!(f, "{d}")?;
            }
        }
        write!(
            f,
            "verdict: {} ({} error(s), {} warning(s), {} check(s) passed)",
            if self.ok() { "APPROVED" } else { "REJECTED" },
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info),
        )
    }
}

/// Typed rejection: the static analyzer proved the configuration unsafe.
///
/// Carried through `anyhow` by `plan_pipeline`, so
/// `StreamPool::new` / `run_streaming` callers can
/// `err.downcast_ref::<AnalysisError>()` and inspect the exact
/// undersized edges and their minimum safe depths.
#[derive(Debug, Clone)]
pub struct AnalysisError {
    /// The Error-severity findings that caused the rejection.
    pub diagnostics: Vec<Diagnostic>,
}

impl fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "static pipeline analysis rejected the configuration ({} error(s))",
            self.diagnostics.len()
        )?;
        for d in &self.diagnostics {
            write!(f, "; {}: {}", d.subject, d.message)?;
            if let Some(depth) = d.min_safe_depth {
                write!(f, " (min safe depth {depth})")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for AnalysisError {}

/// Run every verification pass and return the full report.
///
/// `weights` is optional: without it (e.g. a freshly imported QONNX
/// graph) the range pass falls back to sound dtype worst cases instead
/// of per-channel sums.
pub fn verify(
    g: &Graph,
    weights: Option<&ModelWeights>,
    cfg: &StreamConfig,
    acfg: &AcceleratorConfig,
) -> Result<AnalysisReport> {
    let mut diagnostics = deadlock::check(g, cfg, acfg)?;
    diagnostics.extend(feasibility::check(g, acfg)?);
    diagnostics.extend(ranges::check(g, weights)?);
    Ok(AnalysisReport { diagnostics })
}

/// The cheap structural passes (deadlock + window feasibility) run by
/// `plan_pipeline` before any stage thread spawns.  Returns
/// `Err(AnalysisError)` (downcastable through `anyhow`) on a provable
/// hazard.
pub fn preflight(g: &Graph, cfg: &StreamConfig, acfg: &AcceleratorConfig) -> Result<()> {
    let mut diagnostics = deadlock::check(g, cfg, acfg)?;
    diagnostics.extend(feasibility::check(g, acfg)?);
    AnalysisReport { diagnostics }
        .into_result()
        .map_err(anyhow::Error::new)?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn diag(sev: Severity) -> Diagnostic {
        Diagnostic::new(sev, "fifo.undersized", "b0.skip", "too small")
            .with_values(4, 2128)
            .with_min_safe_depth(2128)
    }

    #[test]
    fn report_verdict_and_counts() {
        let ok = AnalysisReport { diagnostics: vec![diag(Severity::Info)] };
        assert!(ok.ok());
        assert!(ok.clone().into_result().is_ok());
        assert!(format!("{ok}").contains("APPROVED"));

        let bad = AnalysisReport {
            diagnostics: vec![diag(Severity::Info), diag(Severity::Error)],
        };
        assert!(!bad.ok());
        assert_eq!(bad.count(Severity::Error), 1);
        let err = bad.into_result().unwrap_err();
        assert_eq!(err.diagnostics.len(), 1);
        let msg = format!("{err}");
        assert!(msg.contains("b0.skip"), "{msg}");
        assert!(msg.contains("min safe depth 2128"), "{msg}");
    }

    #[test]
    fn report_json_shape() {
        let r = AnalysisReport { diagnostics: vec![diag(Severity::Error)] };
        let j = r.to_json();
        assert_eq!(j.at("status").and_then(|s| s.as_str()), Some("rejected"));
        assert_eq!(j.at("counts/error").and_then(|c| c.as_i64()), Some(1));
        let d = &j.at("diagnostics").and_then(|a| a.as_array()).unwrap()[0];
        assert_eq!(d.get("min_safe_depth").and_then(|v| v.as_i64()), Some(2128));
        assert_eq!(d.get("subject").and_then(|v| v.as_str()), Some("b0.skip"));
    }
}
