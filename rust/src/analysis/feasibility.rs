//! Window/shape feasibility: re-derive the Eq. 16/17 slice spans from the
//! graph and cross-check the planner's `hls::window::slice_plan` output.
//!
//! The planner (`hls::config::configure`) and the executor
//! (`stream::stage`/`stream::line_buffer`) both consume `LayerConfig`'s
//! window geometry; if the recorded plan ever drifted from what the graph
//! implies (a stale config, a hand-edited import, a planner regression),
//! the executor would build a window buffer whose slice spans disagree
//! with the stream distances actually arriving — producing silent wrong
//! answers or stalls rather than a typed error.  This pass recomputes
//! every span from first principles and reports any disagreement before a
//! thread spawns.

use anyhow::Result;

use crate::graph::{infer_shapes, Graph, Op};
use crate::hls::config::AcceleratorConfig;
use crate::hls::window::{buffer_size, slice_plan};

use super::{Diagnostic, Severity};

/// Cross-check every planned conv's window geometry against the graph.
pub fn check(g: &Graph, acfg: &AcceleratorConfig) -> Result<Vec<Diagnostic>> {
    let shapes = infer_shapes(g).map_err(anyhow::Error::new)?;
    let mut out = Vec::new();

    for lc in acfg.convs.values() {
        let subject = format!("{}.window", lc.name);

        // The config must still point at a live conv of the same geometry.
        let node = g.nodes.get(lc.node);
        let conv = match node.map(|n| (&n.op, n)) {
            Some((Op::Conv(a), n)) => Some((a, n)),
            _ => None,
        };
        let Some((attrs, node)) = conv else {
            out.push(Diagnostic::new(
                Severity::Error,
                "window.node-missing",
                &subject,
                "the accelerator configuration references a node that is not \
                 a live conv in the graph",
            ));
            continue;
        };
        let in_shape = node.inputs.first().and_then(|(e, _)| shapes.get(e));
        let Some(in_shape) = in_shape else {
            out.push(Diagnostic::new(
                Severity::Error,
                "window.unshaped",
                &subject,
                "the conv's data input has no inferred shape",
            ));
            continue;
        };
        if (lc.ih, lc.iw, lc.ich) != (in_shape.h, in_shape.w, in_shape.c)
            || lc.k != attrs.k
        {
            out.push(
                Diagnostic::new(
                    Severity::Error,
                    "window.shape-mismatch",
                    &subject,
                    format!(
                        "config records input {}x{}x{} (k={}) but the graph \
                         implies {}x{}x{} (k={})",
                        lc.ih, lc.iw, lc.ich, lc.k,
                        in_shape.h, in_shape.w, in_shape.c, attrs.k
                    ),
                )
                .with_values(lc.iw as i64, in_shape.w as i64),
            );
            continue;
        }

        // Re-derive Eq. 16/17 from the (now-validated) geometry.
        let derived = slice_plan(lc.k, lc.k, lc.iw, lc.ich, lc.ow_par)
            .and_then(|p| buffer_size(lc.k, lc.k, lc.iw, lc.ich, lc.ow_par).map(|b| (p, b)));
        let (plan, cap) = match derived {
            Ok(pb) => pb,
            Err(e) => {
                out.push(Diagnostic::new(
                    Severity::Error,
                    "window.degenerate",
                    &subject,
                    format!("the Eq. 16/17 span cannot be derived: {e}"),
                ));
                continue;
            }
        };
        if lc.window != plan {
            out.push(
                Diagnostic::new(
                    Severity::Error,
                    "window.plan-mismatch",
                    &subject,
                    format!(
                        "planned slice spans {:?} (stride {}) disagree with the \
                         Eq. 16/17 derivation {:?} (stride {})",
                        lc.window.sizes, lc.window.forward_stride,
                        plan.sizes, plan.forward_stride
                    ),
                )
                .with_values(lc.window.total() as i64, plan.total() as i64),
            );
            continue;
        }
        if lc.window_capacity != cap {
            out.push(
                Diagnostic::new(
                    Severity::Error,
                    "window.capacity-mismatch",
                    &subject,
                    format!(
                        "planned window capacity {} disagrees with the Eq. 16/17 \
                         buffer size {cap}",
                        lc.window_capacity
                    ),
                )
                .with_values(lc.window_capacity as i64, cap as i64),
            );
            continue;
        }
        // Eq. 16/17 internal invariant: slice spans sum to the buffer size
        // minus the in-flight window span held by the tasks themselves.
        if plan.total() > cap {
            out.push(
                Diagnostic::new(
                    Severity::Error,
                    "window.invariant",
                    &subject,
                    format!(
                        "slice spans sum to {} which exceeds the Eq. 16/17 \
                         buffer size {cap}",
                        plan.total()
                    ),
                )
                .with_values(plan.total() as i64, cap as i64),
            );
            continue;
        }
        out.push(
            Diagnostic::new(
                Severity::Info,
                "window.ok",
                &subject,
                format!(
                    "{} slices spanning {} of {} elems match the Eq. 16/17 \
                     derivation (ow_par {})",
                    plan.slices(), plan.total(), cap, lc.ow_par
                ),
            )
            .with_values(plan.total() as i64, cap as i64),
        );
    }

    Ok(out)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::models::{arch_by_name, build_optimized_graph, default_exps};
    use crate::stream::{planned_config, StreamConfig};

    fn setup(name: &str) -> (Graph, AcceleratorConfig) {
        let arch = arch_by_name(name).unwrap();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        let acfg = planned_config(name, &g, &StreamConfig::default()).unwrap();
        (g, acfg)
    }

    #[test]
    fn planner_output_is_feasible_for_stock_archs() {
        for name in ["resnet8", "resnet20"] {
            let (g, acfg) = setup(name);
            let diags = check(&g, &acfg).unwrap();
            assert!(
                diags.iter().all(|d| d.severity != Severity::Error),
                "{name}: {diags:?}"
            );
            assert_eq!(
                diags.iter().filter(|d| d.code == "window.ok").count(),
                acfg.convs.len(),
                "{name}: one verified window per conv"
            );
        }
    }

    #[test]
    fn tampered_window_capacity_is_flagged() {
        let (g, mut acfg) = setup("resnet8");
        let id = *acfg.convs.keys().next().unwrap();
        acfg.convs.get_mut(&id).unwrap().window_capacity += 1;
        let diags = check(&g, &acfg).unwrap();
        assert!(diags.iter().any(|d| d.code == "window.capacity-mismatch"));
    }

    #[test]
    fn tampered_slice_plan_is_flagged() {
        let (g, mut acfg) = setup("resnet8");
        let id = *acfg.convs.keys().next().unwrap();
        let lc = acfg.convs.get_mut(&id).unwrap();
        if let Some(s) = lc.window.sizes.first_mut() {
            *s += 1;
        }
        let diags = check(&g, &acfg).unwrap();
        assert!(diags.iter().any(|d| d.code == "window.plan-mismatch"));
    }

    #[test]
    fn stale_node_reference_is_flagged() {
        let (g, mut acfg) = setup("resnet8");
        let id = *acfg.convs.keys().next().unwrap();
        acfg.convs.get_mut(&id).unwrap().node = usize::MAX;
        let diags = check(&g, &acfg).unwrap();
        assert!(diags.iter().any(|d| d.code == "window.node-missing"));
    }
}
