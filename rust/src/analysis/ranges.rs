//! Integer-range analysis: worst-case accumulator magnitudes per layer.
//!
//! The conv kernel (`stream::stage::conv_pos_core`, mirroring the paper's
//! Fig. 4 task) accumulates `bias + (skip << shift) + Σ x·w` in plain
//! `i32`; the naive residual add aligns two operands at the finer
//! exponent in `i64`.  Neither saturates, so a configuration whose
//! worst-case magnitude exceeds the accumulator width computes garbage
//! silently (release builds wrap).  The stock int8 ResNets sit orders of
//! magnitude below the limit — cf. "Low Precision Constant Parameter CNN
//! on FPGA": quantized ranges are tight enough to bound ahead of time —
//! but an imported QONNX graph chooses its own channel counts and
//! exponents, so the bound is re-proved here for every graph.
//!
//! With `ModelWeights` available the bound is exact per output channel
//! (`|b[co]| + A·Σ|w[·,co]| + A·2^shift` with `A = 128`, the largest
//! post-clip activation magnitude); without weights it falls back to the
//! dtype worst case (`|w| ≤ 128`, `|b| ≤ 32768`).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op};
use crate::models::ModelWeights;

use super::{Diagnostic, Severity};

/// Largest post-clip activation magnitude (|i8| including -128).
const ACT_MAX: i128 = 128;
/// Dtype worst cases for the weightless fallback.
const WEIGHT_MAX: i128 = 128;
const BIAS_MAX: i128 = 32768;

fn sat(v: i128) -> i64 {
    i64::try_from(v).unwrap_or(i64::MAX)
}

/// Per-output-channel `Σ|w|` and `max|b|` for one layer, exact from the
/// weight blob when its lengths match the graph geometry, else the dtype
/// worst case.  Returns `(max_co (|b[co]| + in_bound * Σ|w[·,co]|), exact)`.
fn acc_bound(
    weights: Option<&ModelWeights>,
    layer: &str,
    taps_cin: usize,
    cout: usize,
    in_bound: i128,
) -> (i128, bool) {
    if let Some(lw) = weights.and_then(|w| w.layers.get(layer)) {
        // Both conv (KH, KW, CIN, COUT) and fc (CIN, COUT) layouts are
        // row-major with COUT innermost: flat index i maps to co = i % cout.
        if lw.w.data.len() == taps_cin * cout && lw.b.data.len() == cout && cout > 0 {
            let mut wsum = vec![0i128; cout];
            for (i, &v) in lw.w.data.iter().enumerate() {
                wsum[i % cout] += v.unsigned_abs() as i128;
            }
            let worst = (0..cout)
                .map(|co| lw.b.data[co].unsigned_abs() as i128 + in_bound * wsum[co])
                .max()
                .unwrap_or(0);
            return (worst, true);
        }
    }
    (BIAS_MAX + in_bound * WEIGHT_MAX * taps_cin as i128, false)
}

/// Push the severity-graded accumulator diagnostic for one layer.
fn grade(out: &mut Vec<Diagnostic>, subject: String, worst: i128, exact: bool) {
    let basis = if exact { "from the weight blob" } else { "dtype worst case" };
    let (sev, code, verdict) = if worst > i32::MAX as i128 {
        (Severity::Error, "range.overflow", "exceeds the i32 accumulator")
    } else if worst > (i32::MAX / 4) as i128 {
        (Severity::Warning, "range.headroom", "leaves under 2 bits of i32 headroom")
    } else {
        (Severity::Info, "range.ok", "fits the i32 accumulator")
    };
    out.push(
        Diagnostic::new(
            sev,
            code,
            subject,
            format!("worst-case |acc| = {} ({basis}) {verdict}", sat(worst)),
        )
        .with_values(sat(worst), i32::MAX as i64),
    );
}

/// Prove (or refute) accumulator-width safety for every layer.
pub fn check(g: &Graph, weights: Option<&ModelWeights>) -> Result<Vec<Diagnostic>> {
    let shapes = infer_shapes(g).map_err(anyhow::Error::new)?;
    let mut out = Vec::new();
    // Worst-case |value| on every live edge, propagated topologically
    // (`g.live()` yields id order, ids are topological).
    let mut bound: BTreeMap<Edge, i128> = BTreeMap::new();
    let in_of = |n: &crate::graph::Node, i: usize| n.inputs.get(i).map(|(e, _)| *e);
    // Exponent an Add operand arrives at: a raw conv streams accumulators
    // at its acc exponent (stage.rs exp_of contract); weightless, the
    // shape exponent (= in_exp + w_exp for raw outputs) stands in.
    let operand_exp = |e: Edge| -> i32 {
        if let Some(p) = g.nodes.get(e.node) {
            if let Op::Conv(a) = &p.op {
                if a.raw_output {
                    if let Some(lw) = weights.and_then(|w| w.layers.get(&p.name)) {
                        return lw.acc_exp();
                    }
                }
            }
        }
        shapes.get(&e).map_or(0, |s| s.exp)
    };

    for n in g.live() {
        match &n.op {
            Op::Input { .. } => {
                bound.insert(Edge::new(n.id, 0), ACT_MAX);
            }
            Op::Conv(a) => {
                let in_edge = in_of(n, 0);
                let in_bound = in_edge.and_then(|e| bound.get(&e)).copied().unwrap_or(ACT_MAX);
                let taps_cin = a.k * a.k * a.cin;
                let (mut worst, exact) = acc_bound(weights, &n.name, taps_cin, a.cout, in_bound);

                // Fused skip init: `acc += skip << (skip_exp - acc_exp)`.
                let skip = n.inputs.iter().find(|(_, r)| *r == InputRole::SkipInit);
                if let Some((se, _)) = skip {
                    let acc_exp = weights
                        .and_then(|w| w.layers.get(&n.name))
                        .map(|lw| lw.acc_exp())
                        .unwrap_or_else(|| {
                            in_edge.and_then(|e| shapes.get(&e)).map_or(0, |s| s.exp) + a.w_exp
                        });
                    let skip_exp = shapes.get(se).map_or(acc_exp, |s| s.exp);
                    let shift = skip_exp - acc_exp;
                    if shift < 0 {
                        out.push(Diagnostic::new(
                            Severity::Error,
                            "range.skip-shift",
                            format!("{}.skip", n.name),
                            format!(
                                "skip exponent {skip_exp} is below the accumulator \
                                 exponent {acc_exp}: the fused init cannot align \
                                 without losing bits"
                            ),
                        ));
                    } else if shift > 62 {
                        out.push(Diagnostic::new(
                            Severity::Error,
                            "range.skip-shift",
                            format!("{}.skip", n.name),
                            format!(
                                "skip-to-accumulator shift of {shift} bits overflows \
                                 any fixed-point accumulator"
                            ),
                        ));
                    } else {
                        let skip_bound = bound.get(se).copied().unwrap_or(ACT_MAX);
                        worst += skip_bound << shift;
                    }
                }
                grade(&mut out, format!("{}.acc", n.name), worst, exact);

                let out_bound = if a.raw_output { worst } else { ACT_MAX };
                bound.insert(Edge::new(n.id, 0), out_bound);
                if a.forwards_input {
                    bound.insert(Edge::new(n.id, 1), in_bound);
                } else if let Some(ds) = &a.merged_downsample {
                    let ds_taps_cin = ds.k * ds.k * a.cin;
                    let (ds_worst, ds_exact) =
                        acc_bound(weights, &ds.name, ds_taps_cin, ds.cout, in_bound);
                    grade(&mut out, format!("{}.acc", ds.name), ds_worst, ds_exact);
                    // The merged downsample output is requantized to i8.
                    bound.insert(Edge::new(n.id, 1), ACT_MAX);
                }
            }
            Op::Add { .. } => {
                // Naive residual merge: `(a << sa) + (b << sb)` in i64.
                let (ea, ba) = match in_of(n, 0) {
                    Some(e) => (operand_exp(e), bound.get(&e).copied().unwrap_or(ACT_MAX)),
                    None => (0, ACT_MAX),
                };
                let (eb, bb) = match in_of(n, 1) {
                    Some(e) => (operand_exp(e), bound.get(&e).copied().unwrap_or(ACT_MAX)),
                    None => (0, ACT_MAX),
                };
                let lo = ea.min(eb);
                let (sa, sb) = ((ea - lo) as u32, (eb - lo) as u32);
                if sa > 62 || sb > 62 {
                    out.push(Diagnostic::new(
                        Severity::Error,
                        "range.shift",
                        format!("{}.add", n.name),
                        format!(
                            "operand alignment shifts ({sa}, {sb}) exceed the i64 \
                             widening the add stage performs"
                        ),
                    ));
                } else {
                    let sum = (ba << sa) + (bb << sb);
                    if sum > i64::MAX as i128 {
                        out.push(
                            Diagnostic::new(
                                Severity::Error,
                                "range.add-overflow",
                                format!("{}.add", n.name),
                                format!(
                                    "worst-case aligned sum {} exceeds the i64 \
                                     widening accumulator",
                                    sat(sum)
                                ),
                            )
                            .with_values(sat(sum), i64::MAX),
                        );
                    } else {
                        out.push(
                            Diagnostic::new(
                                Severity::Info,
                                "range.ok",
                                format!("{}.add", n.name),
                                format!("worst-case aligned sum {} fits i64", sat(sum)),
                            )
                            .with_values(sat(sum), i64::MAX),
                        );
                    }
                }
                // The add requantizes and clips back to i8.
                bound.insert(Edge::new(n.id, 0), ACT_MAX);
            }
            Op::Linear { cin, cout, .. } => {
                let in_bound = in_of(n, 0)
                    .and_then(|e| bound.get(&e))
                    .copied()
                    .unwrap_or(ACT_MAX);
                let (worst, exact) = acc_bound(weights, &n.name, *cin, *cout, in_bound);
                grade(&mut out, format!("{}.acc", n.name), worst, exact);
                // Logits stream as raw i32.
                bound.insert(Edge::new(n.id, 0), worst);
            }
            Op::Relu | Op::MaxPool { .. } | Op::BatchNorm(_) => {
                // Pointwise / selecting ops never increase magnitude.
                let b = in_of(n, 0).and_then(|e| bound.get(&e)).copied().unwrap_or(ACT_MAX);
                bound.insert(Edge::new(n.id, 0), b);
            }
            Op::GlobalAvgPool { .. } => {
                // Shift-divide then clip to i8.
                bound.insert(Edge::new(n.id, 0), ACT_MAX);
            }
        }
    }

    Ok(out)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::graph::ConvAttrs;
    use crate::models::{
        arch_by_name, build_optimized_graph, build_unoptimized_graph, default_exps,
        synthetic_weights,
    };

    #[test]
    fn stock_archs_fit_i32_with_synthetic_weights() {
        for name in ["resnet8", "resnet20"] {
            let arch = arch_by_name(name).unwrap();
            let weights = synthetic_weights(&arch, 7);
            for g in [
                build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps),
                build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps),
            ] {
                let diags = check(&g, Some(&weights)).unwrap();
                assert!(
                    diags.iter().all(|d| d.severity == Severity::Info),
                    "{name}: {diags:?}"
                );
                assert!(diags.iter().all(|d| d.code == "range.ok"));
            }
        }
    }

    #[test]
    fn weightless_fallback_still_approves_stock_archs() {
        let arch = arch_by_name("resnet8").unwrap();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        let diags = check(&g, None).unwrap();
        assert!(diags.iter().all(|d| d.severity == Severity::Info), "{diags:?}");
        assert!(diags.iter().any(|d| d.message.contains("dtype worst case")));
    }

    #[test]
    fn oversized_import_overflows_and_is_flagged() {
        // A hostile "import": one conv wide enough that even the dtype
        // worst case exceeds i32 (128 * 128 * 9 * cin > 2^31 for
        // cin = 2^17): flagged, not silently wrapped at runtime.
        let mut g = Graph::new();
        let cin = 1 << 17;
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: cin, exp: -7 }, &[]);
        g.add_simple(
            "huge",
            Op::Conv(ConvAttrs {
                cin, cout: 4, k: 3, stride: 1, pad: 1, relu: false,
                w_exp: -8, out_exp: -5,
                merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        let diags = check(&g, None).unwrap();
        let d = diags.iter().find(|d| d.code == "range.overflow").expect("overflow diag");
        assert_eq!(d.subject, "huge.acc");
        assert_eq!(d.severity, Severity::Error);
    }

    #[test]
    fn skip_exponent_below_acc_exponent_is_flagged() {
        // A fused skip whose activation exponent sits below the consumer's
        // accumulator exponent cannot be aligned by a left shift; the
        // executor would refuse at plan time, the analyzer says why.
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 4, exp: -20 }, &[]);
        let attrs = |w_exp| ConvAttrs {
            cin: 4, cout: 4, k: 3, stride: 1, pad: 1, relu: false,
            w_exp, out_exp: -20,
            merged_downsample: None, forwards_input: true, raw_output: false,
        };
        let c0 = g.add_simple("c0", Op::Conv(attrs(-2)), &[Edge::new(i, 0)]);
        // c1's weightless acc exponent is in_exp + w_exp = -20 + 5 = -15,
        // above the forwarded skip's -20: a negative alignment shift.
        g.add(
            "c1",
            Op::Conv(ConvAttrs { forwards_input: false, ..attrs(5) }),
            vec![
                (Edge::new(c0, 0), InputRole::Data),
                (Edge::new(c0, 1), InputRole::SkipInit),
            ],
        );
        let diags = check(&g, None).unwrap();
        assert!(
            diags.iter().any(|d| d.code == "range.skip-shift" && d.subject == "c1.skip"),
            "{diags:?}"
        );
    }
}
