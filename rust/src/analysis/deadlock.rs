//! Deadlock-freedom: worst-case token accounting over the planned
//! FIFO/skip/merge graph.
//!
//! The streaming executor's skip paths are the only edges whose depth is
//! a *liveness* requirement rather than a throughput knob.  In both
//! dataflow forms the skip producer must buffer a bounded skew before
//! its consumer pops the first token:
//!
//! * **fused skip** (`InputRole::SkipInit`, optimized graph): the
//!   consuming conv initializes its accumulator from the skip stream,
//!   but cannot pop until its own window buffer has filled — the
//!   producer must park the consumer's full `ow_par = 1` window span,
//!   Eq. 22 (`hls::window::buffer_size(k, k, iw, ich, 1)`);
//! * **naive skip** (explicit `Add` node, paper Fig. 14): the add pops
//!   element `k` only after the two-conv branch delivers element `k`,
//!   which trails the tee'd producer by the branch's receptive field —
//!   Eq. 21 (`hls::window::skip_buffer_naive`).
//!
//! A declared capacity below the bound means the blocking producer-side
//! tee wedges with certainty once the skew exceeds the FIFO — the
//! Fig. 14 deadlock.  Because `plan_pipeline` sizes these FIFOs from
//! `AcceleratorConfig` (optionally overridden by
//! `StreamConfig::skip_capacity_override`), the accounting here mirrors
//! that sizing exactly and re-derives each bound from the graph, so a
//! planner bug cannot hide behind its own numbers (a planner/analyzer
//! disagreement is itself reported as a warning).

use anyhow::Result;

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op};
use crate::hls::config::AcceleratorConfig;
use crate::hls::window::buffer_size;
use crate::stream::StreamConfig;

use super::{Diagnostic, Severity};

/// The Fig. 14 deadlock message for an undersized skip edge.
fn undersized(
    subject: &str,
    declared: usize,
    required: usize,
    law: &str,
) -> Diagnostic {
    Diagnostic::new(
        Severity::Error,
        "fifo.undersized",
        subject,
        format!(
            "skip FIFO holds {declared} elems but the {law} token-accounting \
             bound requires {required}: the blocking producer-side tee wedges \
             once the skew fills the FIFO (paper Fig. 14 deadlock)"
        ),
    )
    .with_values(declared as i64, required as i64)
    .with_min_safe_depth(required)
}

/// A planner/analyzer disagreement on a skip depth (either direction).
fn mismatch(subject: &str, planned: usize, required: usize, law: &str) -> Diagnostic {
    Diagnostic::new(
        Severity::Warning,
        "fifo.config-mismatch",
        subject,
        format!(
            "planner sized this skip FIFO at {planned} elems but the {law} \
             bound re-derived from the graph is {required}"
        ),
    )
    .with_values(planned as i64, required as i64)
}

fn approved(subject: &str, declared: usize, required: usize, law: &str) -> Diagnostic {
    Diagnostic::new(
        Severity::Info,
        "fifo.ok",
        subject,
        format!("depth {declared} meets the {law} bound {required}"),
    )
    .with_values(declared as i64, required as i64)
}

/// Verify every skip edge against its Eq. 21/22 bound and every planned
/// stream spec against zero-capacity degeneracy.
pub fn check(
    g: &Graph,
    cfg: &StreamConfig,
    acfg: &AcceleratorConfig,
) -> Result<Vec<Diagnostic>> {
    let shapes = infer_shapes(g).map_err(anyhow::Error::new)?;
    let mut out = Vec::new();

    for n in g.live() {
        match &n.op {
            // Fused skip: Eq. 22 — the consumer's own ow_par=1 window span.
            Op::Conv(a) => {
                let sk = n.inputs.iter().find(|(_, r)| *r == InputRole::SkipInit).map(|(e, _)| *e);
                let Some(sk) = sk else { continue };
                let subject = format!("{}.skip", n.name);
                // Eq. 22 is only sound for block-local skew.  A long skip
                // arriving pre-fused (the optimizer never emits one, but an
                // imported graph can) has no bounded-skew law at all: reject
                // it outright rather than bless an Eq. 22 FIFO (Fig. 14).
                if !crate::hls::config::skip_is_block_local(g, Edge::new(n.id, 0), sk) {
                    out.push(Diagnostic::new(
                        Severity::Error,
                        "fifo.nonlocal-fused-skip",
                        &subject,
                        "the fused SkipInit stream consumes a skip that is not \
                         local to the two-conv branch; Eq. 22 sizing is unsound \
                         for its skew — this merge must stay a naive Add with a \
                         full-frame FIFO",
                    ));
                    continue;
                }
                let in_shape = match n.inputs.first().and_then(|(e, _)| shapes.get(e)) {
                    Some(s) => *s,
                    None => {
                        out.push(Diagnostic::new(
                            Severity::Error,
                            "fifo.unshaped",
                            &subject,
                            "the consuming conv's data input has no inferred shape",
                        ));
                        continue;
                    }
                };
                let required = match buffer_size(a.k, a.k, in_shape.w, a.cin, 1) {
                    Ok(b) => b,
                    Err(e) => {
                        out.push(Diagnostic::new(
                            Severity::Error,
                            "fifo.window",
                            &subject,
                            format!("the Eq. 22 bound cannot be derived: {e}"),
                        ));
                        continue;
                    }
                };
                let planned = acfg
                    .convs
                    .get(&n.id)
                    .and_then(|lc| lc.skip_in.as_ref())
                    .map(|s| s.capacity());
                let Some(planned) = planned else {
                    out.push(Diagnostic::new(
                        Severity::Error,
                        "fifo.config-missing",
                        &subject,
                        "the accelerator configuration lost this conv's skip stream",
                    ));
                    continue;
                };
                if planned != required {
                    out.push(mismatch(&subject, planned, required, "Eq. 22"));
                }
                let declared = cfg.skip_capacity_override.unwrap_or(planned);
                if declared < required {
                    out.push(undersized(&subject, declared, required, "Eq. 22"));
                } else {
                    out.push(approved(&subject, declared, required, "Eq. 22"));
                }
            }
            // Naive skip: one FIFO per skip operand.  Branch-local operands
            // answer to Eq. 21 (the two-conv receptive field); long skips
            // answer to the full-frame bound of the skip tensor (the long
            // branch may hold back its first pop for the whole frame).
            Op::Add { .. } => {
                for (i, (sk, _)) in n.inputs.iter().enumerate().skip(1) {
                    let subject = if i == 1 {
                        format!("{}.skip", n.name)
                    } else {
                        format!("{}.skip{i}", n.name)
                    };
                    let planned =
                        acfg.adds.get(&n.id).and_then(|a| a.skips.get(i - 1)).copied();
                    let Some(planned) = planned else {
                        out.push(Diagnostic::new(
                            Severity::Error,
                            "fifo.config-missing",
                            &subject,
                            "the accelerator configuration has no sizing for this \
                             skip operand",
                        ));
                        continue;
                    };
                    // Re-derive the bound from the graph rather than trusting
                    // the planner's stored numbers — via the same shared
                    // `local_skip_bound` walk `hls::config::configure` uses,
                    // so the locality predicate cannot drift between the two.
                    let local = crate::hls::config::local_skip_bound(
                        g,
                        &shapes,
                        n.inputs[0].0,
                        *sk,
                    );
                    let (required, law) = match local {
                        Some(r) => (r, "Eq. 21"),
                        None => {
                            let Some(s) = shapes.get(sk) else {
                                out.push(Diagnostic::new(
                                    Severity::Error,
                                    "fifo.unshaped",
                                    &subject,
                                    "the skip operand has no inferred shape",
                                ));
                                continue;
                            };
                            (s.h * s.w * s.c, "full-frame")
                        }
                    };
                    if planned != required {
                        out.push(mismatch(&subject, planned, required, law));
                    }
                    let declared = cfg.skip_capacity_override.unwrap_or(planned);
                    if declared < required {
                        out.push(undersized(&subject, declared, required, law));
                    } else {
                        out.push(approved(&subject, declared, required, law));
                    }
                }
            }
            _ => {}
        }
    }

    // Degenerate stream specs: a zero-capacity FIFO can never admit a
    // token, so the first push wedges regardless of the topology.  This
    // only arises from hostile inputs (e.g. an imported QONNX conv with
    // zero output channels), never from the stock architectures.
    for lc in acfg.convs.values() {
        for (what, cap) in [
            ("out", lc.out_stream.capacity()),
            ("param", lc.param_stream.capacity()),
        ] {
            if cap == 0 {
                out.push(Diagnostic::new(
                    Severity::Error,
                    "fifo.zero-capacity",
                    format!("{}.{what}", lc.name),
                    "planned stream has zero capacity; the first push can never \
                     complete",
                ));
            }
        }
        if let Some(m) = &lc.merged_ds {
            if m.out_stream.capacity() == 0 {
                out.push(Diagnostic::new(
                    Severity::Error,
                    "fifo.zero-capacity",
                    format!("{}.out", m.name),
                    "planned stream has zero capacity; the first push can never \
                     complete",
                ));
            }
        }
    }

    Ok(out)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hls::window::{skip_buffer_naive, skip_buffer_optimized};
    use crate::models::{arch_by_name, build_optimized_graph, build_unoptimized_graph, default_exps};
    use crate::stream::{planned_config, StreamConfig};

    fn naive_setup() -> (Graph, AcceleratorConfig, StreamConfig) {
        let arch = arch_by_name("resnet8").unwrap();
        let (act, w) = default_exps(&arch);
        let g = build_unoptimized_graph(&arch, &act, &w);
        let cfg = StreamConfig { naive_add: true, ..StreamConfig::default() };
        let acfg = planned_config("resnet8", &g, &cfg).unwrap();
        (g, acfg, cfg)
    }

    #[test]
    fn stock_configs_have_no_errors() {
        for name in ["resnet8", "resnet20", "skipnet", "longskipnet", "tiednet"] {
            let arch = arch_by_name(name).unwrap();
            let (act, w) = default_exps(&arch);
            let g = build_optimized_graph(&arch, &act, &w);
            let cfg = StreamConfig::default();
            let acfg = planned_config(name, &g, &cfg).unwrap();
            let diags = check(&g, &cfg, &acfg).unwrap();
            assert!(
                diags.iter().all(|d| d.severity != Severity::Error),
                "{name}: {diags:?}"
            );
            // Every fused skip is individually verified.
            assert!(diags.iter().any(|d| d.code == "fifo.ok"), "{name}");
        }
    }

    #[test]
    fn fig14_override_is_flagged_with_edge_and_min_depth() {
        // The paper's Fig. 14 experiment: Eq. 22-sized skip FIFOs on the
        // naive dataflow.  Statically rejected, naming the first block's
        // edge with the exact Eq. 21 minimum safe depth.
        let (g, acfg, mut cfg) = naive_setup();
        cfg.skip_capacity_override = Some(skip_buffer_optimized(3, 3, 32, 16));
        let diags = check(&g, &cfg, &acfg).unwrap();
        let d = diags
            .iter()
            .find(|d| d.code == "fifo.undersized" && d.subject == "s0b0_add.skip")
            .expect("undersized diagnostic for the first block");
        assert_eq!(d.min_safe_depth, Some(skip_buffer_naive(3, 3, 32, 16, 3, 3)));
        assert_eq!(d.measured, Some(skip_buffer_optimized(3, 3, 32, 16) as i64));
    }

    #[test]
    fn undersized_long_skip_is_rejected_with_its_edge_named() {
        // skipnet's r1 merge takes an identity skip (Eq. 21 bound) and a
        // long skip back to the stem (full-frame bound).  The planner's
        // own sizing passes; capping every skip at Eq. 21 starves exactly
        // the long operand, and the diagnostic names it.
        let arch = arch_by_name("skipnet").unwrap();
        let (act, w) = default_exps(&arch);
        let g = build_unoptimized_graph(&arch, &act, &w);
        let mut cfg = StreamConfig { naive_add: true, ..StreamConfig::default() };
        let acfg = planned_config("skipnet", &g, &cfg).unwrap();

        let diags = check(&g, &cfg, &acfg).unwrap();
        assert!(diags.iter().all(|d| d.severity != Severity::Error), "{diags:?}");
        assert!(
            diags.iter().any(|d| d.code == "fifo.ok" && d.subject == "r1_add.skip2"),
            "the long skip gets its own verified subject: {diags:?}"
        );

        cfg.skip_capacity_override = Some(skip_buffer_naive(3, 3, 32, 16, 3, 3));
        let diags = check(&g, &cfg, &acfg).unwrap();
        let bad: Vec<_> = diags.iter().filter(|d| d.code == "fifo.undersized").collect();
        assert_eq!(bad.len(), 1, "{diags:?}");
        assert_eq!(bad[0].subject, "r1_add.skip2");
        assert_eq!(bad[0].min_safe_depth, Some(32 * 32 * 16), "full-frame stem tensor");
    }

    #[test]
    fn two_operand_long_skip_stays_naive_and_answers_to_full_frame() {
        // longskipnet's r1 merge has the fusable *shape* (2 operands, one
        // skip) but its skip is a long skip to the stem: the optimizer must
        // keep it a naive island, the planner must size it full-frame, and
        // an Eq. 21-sized override must be rejected naming exactly that
        // edge — the static gate the fused form would have bypassed.
        let arch = arch_by_name("longskipnet").unwrap();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        let mut cfg = StreamConfig::default();
        let acfg = planned_config("longskipnet", &g, &cfg).unwrap();

        let diags = check(&g, &cfg, &acfg).unwrap();
        assert!(diags.iter().all(|d| d.severity != Severity::Error), "{diags:?}");
        assert!(
            diags.iter().any(|d| d.code == "fifo.ok" && d.subject == "r1_add.skip"),
            "the surviving naive island is individually verified: {diags:?}"
        );

        cfg.skip_capacity_override = Some(skip_buffer_naive(3, 3, 32, 16, 3, 3));
        let diags = check(&g, &cfg, &acfg).unwrap();
        let bad: Vec<_> = diags.iter().filter(|d| d.code == "fifo.undersized").collect();
        assert_eq!(bad.len(), 1, "{diags:?}");
        assert_eq!(bad[0].subject, "r1_add.skip");
        assert_eq!(bad[0].min_safe_depth, Some(32 * 32 * 16), "full-frame stem tensor");
    }

    #[test]
    fn pre_fused_long_skip_is_rejected_outright() {
        // The optimizer never emits a SkipInit on a non-local skip, but an
        // imported graph can arrive that way.  Eq. 22 has no sound bound
        // for it, so the verifier must error instead of approving.
        use crate::graph::{ConvAttrs, Edge, InputRole};
        let attrs = || ConvAttrs {
            cin: 8, cout: 8, k: 3, stride: 1, pad: 1, relu: false,
            w_exp: -8, out_exp: -5, merged_downsample: None,
            forwards_input: false, raw_output: false,
        };
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 16, w: 16, c: 8, exp: -7 }, &[]);
        let s = g.add_simple("s", Op::Conv(attrs()), &[Edge::new(i, 0)]);
        let m = g.add_simple("m", Op::Conv(attrs()), &[Edge::new(s, 0)]);
        let c0 = g.add_simple("c0", Op::Conv(attrs()), &[Edge::new(m, 0)]);
        let c1 = g.add(
            "c1",
            Op::Conv(attrs()),
            vec![(Edge::new(c0, 0), InputRole::Data), (Edge::new(s, 0), InputRole::SkipInit)],
        );
        let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(c1, 0)]);
        g.add_simple("fc", Op::Linear { cin: 8, cout: 10, w_exp: -8 }, &[Edge::new(pool, 0)]);
        g.validate().unwrap();

        let cfg = StreamConfig::default();
        let acfg = planned_config("prefused", &g, &cfg).unwrap();
        let diags = check(&g, &cfg, &acfg).unwrap();
        let d = diags
            .iter()
            .find(|d| d.code == "fifo.nonlocal-fused-skip")
            .expect("nonlocal fused skip must be an error");
        assert_eq!(d.severity, Severity::Error);
        assert_eq!(d.subject, "c1.skip");
    }

    #[test]
    fn naive_eq21_depths_are_approved() {
        let (g, acfg, cfg) = naive_setup();
        let diags = check(&g, &cfg, &acfg).unwrap();
        assert!(diags.iter().all(|d| d.severity != Severity::Error), "{diags:?}");
        assert_eq!(
            diags.iter().filter(|d| d.code == "fifo.ok").count(),
            3,
            "one verified skip per residual block"
        );
    }
}
