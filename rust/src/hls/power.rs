//! Power / energy model (paper Table 3's Power column and the Section V
//! energy argument).
//!
//! The paper measures board power; we estimate it from resource activity
//! with per-resource dynamic coefficients in the range published for
//! Zynq UltraScale+ fabrics, calibrated against the paper's own rows
//! (our ResNet20/KV260 3.61 W, ResNet8/Ultra96 0.56 W).  The absolute
//! numbers are indicative; the *energy-per-frame comparison* (Section V:
//! "lower latency also means lower energy") is the reproduced claim and
//! only needs relative fidelity.

use super::boards::Board;
use super::resources::ResourceReport;

/// Dynamic power coefficients (mW per active unit at 100% toggle, scaled
/// by clock in GHz).
const MW_PER_DSP_GHZ: f64 = 9.0;
const MW_PER_KLUT_GHZ: f64 = 90.0;
const MW_PER_BRAM_GHZ: f64 = 4.5;
const MW_PER_URAM_GHZ: f64 = 9.0;
/// Static + PS-side baseline per board class (W).
const STATIC_W_ULTRA96: f64 = 0.25;
const STATIC_W_KV260: f64 = 1.30;

/// A power/energy estimate for one design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerEstimate {
    pub static_w: f64,
    pub dynamic_w: f64,
    /// Millijoules per frame at the given FPS.
    pub mj_per_frame: f64,
}

impl PowerEstimate {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.dynamic_w
    }
}

/// Estimate power for a resource report on a board, with `activity` the
/// average toggle factor of the compute fabric (the dataflow pipeline
/// keeps PEs busy in steady state; 0.6 reflects the balanced-but-stalling
/// reality the simulator measures).
pub fn estimate_power(rep: &ResourceReport, board: &Board, fps: f64, activity: f64) -> PowerEstimate {
    let ghz = board.clock_mhz / 1e3;
    let dynamic_mw = activity
        * ghz
        * (MW_PER_DSP_GHZ * rep.dsps as f64
            + MW_PER_KLUT_GHZ * rep.luts as f64 / 1e3
            + MW_PER_BRAM_GHZ * rep.bram36 as f64
            + MW_PER_URAM_GHZ * rep.urams as f64);
    let static_w = if board.urams > 0 { STATIC_W_KV260 } else { STATIC_W_ULTRA96 };
    let total = static_w + dynamic_mw / 1e3;
    PowerEstimate {
        static_w,
        dynamic_w: dynamic_mw / 1e3,
        mj_per_frame: if fps > 0.0 { total / fps * 1e3 } else { f64::NAN },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::boards::{KV260, ULTRA96};

    fn rep(dsps: u64, kluts: f64, bram: u64, urams: u64) -> ResourceReport {
        ResourceReport {
            dsps,
            luts: (kluts * 1e3) as u64,
            ffs: (kluts * 1e3) as u64,
            bram36: bram,
            urams,
            lutram_luts: 0,
        }
    }

    #[test]
    fn calibration_lands_near_paper_rows() {
        // Paper: ResNet20/KV260 3.61 W at 626 DSP / 81.2 kLUT / 73.5 BRAM / 64 URAM.
        let p = estimate_power(&rep(626, 81.2, 74, 64), &KV260, 7601.0, 0.6);
        assert!((2.0..=5.5).contains(&p.total_w()), "KV260 r20: {} W", p.total_w());
        // Paper: ResNet8/Ultra96 0.56 W at 360 DSP / 46.4 kLUT / 54 BRAM.
        let p = estimate_power(&rep(360, 46.4, 54, 0), &ULTRA96, 12_971.0, 0.6);
        assert!((0.4..=1.6).contains(&p.total_w()), "U96 r8: {} W", p.total_w());
    }

    #[test]
    fn energy_tracks_latency_at_equal_power_class() {
        // Section V's argument: same board, same utilization class, lower
        // latency => lower energy per frame.
        let r = rep(626, 81.2, 74, 64);
        let fast = estimate_power(&r, &KV260, 7601.0, 0.6);
        let slow = estimate_power(&r, &KV260, 2000.0, 0.6);
        assert!(fast.mj_per_frame < slow.mj_per_frame);
    }

    #[test]
    fn kv260_static_floor_exceeds_ultra96() {
        let p_kv = estimate_power(&rep(100, 10.0, 10, 4), &KV260, 1000.0, 0.5);
        let p_u96 = estimate_power(&rep(100, 10.0, 10, 0), &ULTRA96, 1000.0, 0.5);
        assert!(p_kv.static_w > p_u96.static_w);
    }
}
