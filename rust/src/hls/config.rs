//! Accelerator configuration: per-task template parameters derived from
//! the (optimized or naive) graph, the ILP allocation, and the board.
//!
//! This is the Rust equivalent of the paper's configuration Python script:
//! it decides every template parameter of the C++ task library (unrolls,
//! stream depths, buffer partitions) and feeds the simulator, the resource
//! estimator and the code generator.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op, TensorShape};
use crate::ilp::Allocation;

use super::boards::Board;
use super::packing::{chain_plan, ChainPlan};
use super::streams::{output_stream, parameter_stream, skip_stream, StreamSpec};
use super::window::{buffer_size, skip_buffer_naive, slice_plan, SlicePlan};

/// Per-convolution task configuration.
#[derive(Debug, Clone)]
pub struct LayerConfig {
    pub name: String,
    pub node: usize,
    // Geometry.
    pub ich: usize,
    pub och: usize,
    pub ih: usize,
    pub iw: usize,
    pub oh: usize,
    pub ow: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    // Parallelism.
    pub och_par: usize,
    pub ow_par: usize,
    // Derived.
    pub och_groups: usize,
    /// Weights consumed per cycle (`cw_i = och_par * fh * fw`, Sec. III-D).
    pub cw: usize,
    pub macs: u64,
    pub cycles: u64,
    pub dsps: u64,
    pub chain: ChainPlan,
    pub window: SlicePlan,
    pub window_capacity: usize,
    pub param_stream: StreamSpec,
    pub out_stream: StreamSpec,
    /// Skip stream feeding this conv's accumulator init (optimized form).
    pub skip_in: Option<StreamSpec>,
    /// This task also computes a merged pointwise downsample (loop merge).
    pub merged_ds: Option<MergedDsConfig>,
    /// This task forwards its input on port 1 (temporal reuse).
    pub forwards_input: bool,
    /// Parameter storage bytes (int8 weights + int16 bias).
    pub param_bytes: usize,
}

/// Configuration of a loop-merged downsample sub-task.
#[derive(Debug, Clone)]
pub struct MergedDsConfig {
    pub name: String,
    pub och: usize,
    pub och_par: usize,
    pub cw: usize,
    pub dsps: u64,
    pub param_bytes: usize,
    pub out_stream: StreamSpec,
}

/// Residual-add task configuration (exists only in the *naive* dataflow;
/// the optimized graph fuses it away).
#[derive(Debug, Clone)]
pub struct AddConfig {
    pub name: String,
    pub node: usize,
    /// First skip operand's FIFO capacity required to avoid deadlock
    /// (Eq. 21's receptive-field bound when the operand is block-local).
    pub skip_fifo: usize,
    /// Per-skip-operand FIFO capacities, one per add input port `1..N`
    /// (`skips[0] == skip_fifo`).  Block-local operands get the Eq. 21
    /// receptive-field bound; long skips (reaching past the two-conv
    /// branch) get the sound full-frame bound of the skip tensor.
    pub skips: Vec<usize>,
    pub elems: usize,
}

/// Whole-accelerator configuration.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    pub arch_name: String,
    pub board: Board,
    pub ow_par: usize,
    pub convs: BTreeMap<usize, LayerConfig>,
    pub adds: BTreeMap<usize, AddConfig>,
    /// Steady-state cycles per frame (bottleneck task).
    pub cycles_per_frame: u64,
    pub dsps_used: u64,
}

impl AcceleratorConfig {
    /// FPS at the board clock.
    pub fn fps(&self) -> f64 {
        self.board.clock_mhz * 1e6 / self.cycles_per_frame as f64
    }

    /// Single-frame latency estimate in cycles: the dataflow pipeline's
    /// fill time — the sum over the longest path of each task's time to
    /// first output (window-buffer fill) plus the bottleneck interval.
    pub fn latency_cycles(&self) -> u64 {
        // Fill: each conv must buffer B_i activations before producing;
        // producers emit och per cycle-group.  A close analytic bound is
        // Σ_i (B_i / och_prev_rate) + cycles_per_frame; the simulator
        // measures it exactly, this is the quick estimate.
        let fill: u64 = self
            .convs
            .values()
            .map(|c| (c.window_capacity / c.ich.max(1)) as u64)
            .sum();
        fill + self.cycles_per_frame
    }

    /// Total skip-connection buffering in activations.
    pub fn skip_buffer_total(&self) -> usize {
        let fused: usize = self
            .convs
            .values()
            .filter_map(|c| c.skip_in.as_ref().map(|s| s.capacity()))
            .sum();
        let naive: usize = self.adds.values().flat_map(|a| a.skips.iter()).sum();
        fused + naive
    }

    /// Total parameter bytes.
    pub fn param_bytes(&self) -> usize {
        self.convs
            .values()
            .map(|c| c.param_bytes + c.merged_ds.as_ref().map_or(0, |m| m.param_bytes))
            .sum()
    }
}

/// Build the configuration for a graph + allocation on a board.
///
/// The allocation is keyed by layer *name* and must cover every conv in
/// the graph (including merged downsamples, which the ILP sees as layers).
pub fn configure(
    arch_name: &str,
    g: &Graph,
    alloc: &Allocation,
    board: &Board,
    ow_par: usize,
) -> Result<AcceleratorConfig> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let mut convs = BTreeMap::new();
    let mut adds = BTreeMap::new();
    let mut dsps_used = 0u64;
    let mut bottleneck = 0u64;

    for n in g.live() {
        match &n.op {
            Op::Conv(a) => {
                let in_shape = shapes[&n.inputs[0].0];
                let out_shape = shapes[&Edge::new(n.id, 0)];
                let la = alloc
                    .layer(&n.name)
                    .ok_or_else(|| anyhow!("no allocation for layer {}", n.name))?;
                let taps = a.k * a.k;
                let macs = (out_shape.h * out_shape.w * a.cout * a.cin * taps) as u64;
                let cycles = macs.div_ceil(la.cp);
                let param_bytes = taps * a.cin * a.cout + 2 * a.cout;
                // Window geometry can be unsatisfiable (e.g. the widened
                // ow_par window on a narrow late-stage row): surface the
                // typed WindowError with the layer name, never underflow.
                let win_err = |e| anyhow!("{}: {e}", n.name);
                let window =
                    slice_plan(a.k, a.k, in_shape.w, a.cin, ow_par).map_err(win_err)?;
                let window_capacity =
                    buffer_size(a.k, a.k, in_shape.w, a.cin, ow_par).map_err(win_err)?;
                let skip_in = match n.inputs.iter().find(|(_, r)| *r == InputRole::SkipInit) {
                    Some(_) => Some(skip_stream(
                        // Eq. 22 sizes the skip at the consumer's own
                        // (unwidened, ow_par = 1) window-buffer depth.
                        buffer_size(a.k, a.k, in_shape.w, a.cin, 1).map_err(win_err)?,
                    )),
                    None => None,
                };
                let host_groups = a.cout.div_ceil(la.och_par);
                let merged_ds = a.merged_downsample.as_ref().map(|m| {
                    // The merged loop iterates the host's och_groups; the
                    // downsample must finish its channels within that
                    // shadow, so its unroll is at least ceil(och_ds /
                    // host_groups) — usually more than the ILP's isolated
                    // choice (its c_i is tiny), never less.
                    let ilp_p = alloc.layer(&m.name).map_or(1, |l| l.och_par);
                    let ds_och_par = ilp_p.max(m.cout.div_ceil(host_groups));
                    let ds_taps = m.k * m.k;
                    MergedDsConfig {
                        name: m.name.clone(),
                        och: m.cout,
                        och_par: ds_och_par,
                        cw: ds_och_par * ds_taps,
                        dsps: (ds_taps * ds_och_par) as u64,
                        param_bytes: ds_taps * a.cin * m.cout + 2 * m.cout,
                        out_stream: output_stream(m.cout, ds_och_par, ow_par),
                    }
                });
                dsps_used += la.dsps + merged_ds.as_ref().map_or(0, |m| m.dsps);
                bottleneck = bottleneck.max(cycles);
                convs.insert(
                    n.id,
                    LayerConfig {
                        name: n.name.clone(),
                        node: n.id,
                        ich: a.cin,
                        och: a.cout,
                        ih: in_shape.h,
                        iw: in_shape.w,
                        oh: out_shape.h,
                        ow: out_shape.w,
                        k: a.k,
                        stride: a.stride,
                        pad: a.pad,
                        relu: a.relu,
                        och_par: la.och_par,
                        ow_par,
                        och_groups: a.cout.div_ceil(la.och_par),
                        cw: la.och_par * taps,
                        macs,
                        cycles,
                        dsps: la.dsps,
                        chain: chain_plan(taps),
                        window,
                        window_capacity,
                        param_stream: parameter_stream(la.och_par, taps),
                        out_stream: output_stream(a.cout, la.och_par, ow_par),
                        skip_in,
                        merged_ds,
                        forwards_input: a.forwards_input,
                        param_bytes,
                    },
                );
            }
            Op::Add { .. } => {
                // Naive dataflow: size each skip operand's FIFO.  Operands
                // local to the two-conv long branch get the receptive-field
                // bound (Eq. 21); long skips reaching past it get the
                // full-frame bound of the skip tensor, the sound worst case
                // (every element may arrive before the long branch drains).
                let skips: Vec<usize> = n
                    .inputs
                    .iter()
                    .skip(1)
                    .map(|(sk, _)| {
                        local_skip_bound(g, &shapes, n.inputs[0].0, *sk).unwrap_or_else(|| {
                            let s = shapes[sk];
                            s.h * s.w * s.c
                        })
                    })
                    .collect();
                let s: TensorShape = shapes[&Edge::new(n.id, 0)];
                adds.insert(
                    n.id,
                    AddConfig {
                        name: n.name.clone(),
                        node: n.id,
                        skip_fifo: skips.first().copied().unwrap_or(0),
                        skips,
                        elems: s.h * s.w * s.c,
                    },
                );
            }
            _ => {}
        }
    }

    Ok(AcceleratorConfig {
        arch_name: arch_name.to_string(),
        board: board.clone(),
        ow_par,
        convs,
        adds,
        cycles_per_frame: bottleneck,
        dsps_used,
    })
}

/// Walk the add's two-conv long branch and decide whether skip operand
/// `sk` is local to it.  "Local" means the operand is conv0's own input
/// tensor, conv0's forwarding port (temporal reuse), or the output of a
/// sibling conv reading conv0's input (the downsample).  Returns the
/// geometry needed for the Eq. 21 bound (conv0's kernel + input edge,
/// conv1's kernel), or `None` for anything else — a long skip.
fn block_local_geometry(g: &Graph, long_edge: Edge, sk: Edge) -> Option<(usize, Edge, usize)> {
    let conv1 = g.node(long_edge.node);
    let c1k = match &conv1.op {
        Op::Conv(a) => a.k,
        _ => return None,
    };
    let conv0_id = conv1.inputs.first()?.0.node;
    let conv0 = g.node(conv0_id);
    let (c0k, c0_in_edge) = match &conv0.op {
        Op::Conv(a) => (a.k, conv0.inputs.first()?.0),
        _ => return None,
    };
    let sibling = sk.port == 0
        && !g.node(sk.node).dead
        && matches!(&g.node(sk.node).op, Op::Conv(_))
        && g.node(sk.node).inputs.first().map(|(e, _)| *e) == Some(c0_in_edge);
    if sk != c0_in_edge && sk != Edge::new(conv0_id, 1) && !sibling {
        return None;
    }
    Some((c0k, c0_in_edge, c1k))
}

/// Whether skip operand `sk` of a merge whose long branch is `long_edge`
/// is block-local — the precondition for every bounded-skew skip form:
/// the Eq. 21 naive bound *and* the Eq. 22 fused `SkipInit` stream.  A
/// long skip (reaching past the two-conv branch) may hold its first pop
/// back for the whole frame, so only the full-frame FIFO is sound and
/// add fusion must not apply.
pub(crate) fn skip_is_block_local(g: &Graph, long_edge: Edge, sk: Edge) -> bool {
    block_local_geometry(g, long_edge, sk).is_some()
}

/// Eq. 21 receptive-field bound for a skip operand that is local to the
/// add's two-conv long branch, or `None` for anything else (a long skip),
/// where only the full-frame bound is sound.  Shared by `configure` and
/// the deadlock verifier so the two derivations cannot drift.
pub(crate) fn local_skip_bound(
    g: &Graph,
    shapes: &BTreeMap<Edge, TensorShape>,
    long_edge: Edge,
    sk: Edge,
) -> Option<usize> {
    let (c0k, c0_in_edge, c1k) = block_local_geometry(g, long_edge, sk)?;
    let c0_in = *shapes.get(&c0_in_edge)?;
    Some(skip_buffer_naive(c0k, c0k, c0_in.w, c0_in.c, c1k, c1k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::boards::{KV260, ULTRA96};
    use crate::ilp::{loads_from_arch, solve};
    use crate::models::{build_optimized_graph, build_unoptimized_graph, default_exps, resnet8};

    fn cfg_for(board: &Board, optimized: bool) -> AcceleratorConfig {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let g = if optimized {
            build_optimized_graph(&arch, &act, &w)
        } else {
            build_unoptimized_graph(&arch, &act, &w)
        };
        let alloc = solve(&loads_from_arch(&arch, 2), board.n_par() as u64).unwrap();
        configure(&arch.name, &g, &alloc, board, 2).unwrap()
    }

    #[test]
    fn optimized_config_has_no_add_tasks() {
        let c = cfg_for(&ULTRA96, true);
        assert!(c.adds.is_empty());
        assert_eq!(c.convs.len(), 7, "9 convs - 2 merged downsamples");
        let merged = c.convs.values().filter(|l| l.merged_ds.is_some()).count();
        assert_eq!(merged, 2);
        assert!(c.fps() > 1000.0);
    }

    #[test]
    fn naive_config_skip_buffers_double() {
        let opt = cfg_for(&KV260, true);
        let naive = cfg_for(&KV260, false);
        let r = opt.skip_buffer_total() as f64 / naive.skip_buffer_total() as f64;
        // Paper Eq. 23: R_sc = 0.5 for every block.
        assert!((r - 0.5).abs() < 0.05, "R_sc = {r}");
    }

    #[test]
    fn unsatisfiable_window_geometry_is_a_typed_configure_error() {
        // Regression: an ow_par too wide for a late-stage 8-wide row used
        // to underflow inside slice_plan; configure must now surface the
        // typed WindowError tagged with the offending layer.
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        let alloc = solve(&loads_from_arch(&arch, 2), KV260.n_par() as u64).unwrap();
        let err = configure(&arch.name, &g, &alloc, &KV260, 16).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("widened window"),
            "expected the WindowError message, got: {msg}"
        );
    }

    #[test]
    fn parameter_bandwidth_matches_unroll() {
        let c = cfg_for(&ULTRA96, true);
        for l in c.convs.values() {
            assert_eq!(l.cw, l.och_par * l.k * l.k);
            assert_eq!(l.param_stream.token, l.cw);
            assert_eq!(l.och_groups, l.och.div_ceil(l.och_par));
            assert!(l.och_groups * l.och_par >= l.och);
        }
    }
}
