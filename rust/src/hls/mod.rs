//! HLS-style design layer: everything the paper's Python configuration
//! script + templated C++ library decide at code-generation time.
//!
//! * [`boards`] — target platforms (Table 2);
//! * [`window`] — window-buffer geometry and skip buffering (Eqs. 16–23);
//! * [`packing`] — the WP487 DSP packing model, bit-exact (Section III-C);
//! * [`streams`] — inter-task FIFO sizing (Section III-E);
//! * [`config`] — per-task template parameters for a full accelerator;
//! * [`resources`] — LUT/FF/DSP/BRAM/URAM estimation + resource closure;
//! * [`codegen`] — the generated C++ top function (Section III-B).

pub mod boards;
pub mod codegen;
pub mod config;
pub mod packing;
pub mod power;
pub mod resources;
pub mod streams;
pub mod window;

pub use boards::{board_by_name, Board, BOARDS, KV260, ULTRA96};
pub use config::{AcceleratorConfig, LayerConfig};
pub use resources::{fit_to_board, ResourceReport};
