//! Window-buffer geometry (paper Section III-F) and skip-connection
//! buffering (Section III-G) — Eqs. 16–23.
//!
//! The window buffer (line buffer) retains just enough of the depth-first
//! input stream to emit one `fh x fw` window per cycle; it is physically a
//! chain of FIFO slices (Figs. 7/9) whose sizes are the stream distances
//! between window elements.

/// Why a window-buffer geometry cannot be built.
///
/// Every sizing entry point validates before computing so that no
/// reachable layer geometry can underflow `usize` arithmetic (a debug
/// panic / release wraparound for e.g. 8-wide rows with `fw = 5` and a
/// large `--ow-par`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WindowError {
    /// `fh`, `fw` or `ow_par` of zero describes no window at all.
    Degenerate { fh: usize, fw: usize, ow_par: usize },
    /// The widened window `fw_eff = fw + ow_par - 1` does not fit one
    /// padded input row (`fw_eff > iw + 1`): the Eq. 16/17 stream
    /// distance `S2 = (iw - fw_eff + 1) * ich` would be negative, i.e.
    /// there is no stream position at which all `ow_par` adjacent
    /// computation windows exist.
    TooWide { fw_eff: usize, iw: usize },
}

impl std::fmt::Display for WindowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WindowError::Degenerate { fh, fw, ow_par } => write!(
                f,
                "degenerate window geometry (fh={fh}, fw={fw}, ow_par={ow_par}): \
                 every factor must be >= 1"
            ),
            WindowError::TooWide { fw_eff, iw } => write!(
                f,
                "widened window fw_eff = fw + ow_par - 1 = {fw_eff} exceeds the \
                 {iw}-wide input row (+1): reduce ow_par or the filter width"
            ),
        }
    }
}

impl std::error::Error for WindowError {}

/// The shared validity invariant of Eqs. 16/17: non-degenerate window,
/// and the `ow_par`-widened window spans at most `iw + 1` columns.
fn validate(fh: usize, fw: usize, iw: usize, ow_par: usize) -> Result<(), WindowError> {
    if fh == 0 || fw == 0 || ow_par == 0 {
        return Err(WindowError::Degenerate { fh, fw, ow_par });
    }
    let fw_eff = fw + ow_par - 1;
    if fw_eff > iw + 1 {
        return Err(WindowError::TooWide { fw_eff, iw });
    }
    Ok(())
}

/// Window buffer size in activations for `ow_par = 1` (Eq. 16):
/// `B_i = [(fh-1)*iw + fw - 1] * ich`.
///
/// Infallible literal formula; callers guarantee `fh, fw >= 1` (use
/// [`buffer_size`] for validated sizing).
pub fn buffer_size_owpar1(fh: usize, fw: usize, iw: usize, ich: usize) -> usize {
    ((fh - 1) * iw + fw - 1) * ich
}

/// Window buffer size for `ow_par = 2` (Eq. 17):
/// `B_i = [(fh-1)*iw + fw] * ich` — one extra column ("the overhead with
/// respect to (16) is minimal").
pub fn buffer_size_owpar2(fh: usize, fw: usize, iw: usize, ich: usize) -> usize {
    ((fh - 1) * iw + fw) * ich
}

/// Window buffer size for the configured `ow_par`, validated: errors
/// instead of underflowing when the widened window cannot fit the row.
pub fn buffer_size(
    fh: usize,
    fw: usize,
    iw: usize,
    ich: usize,
    ow_par: usize,
) -> Result<usize, WindowError> {
    validate(fh, fw, iw, ow_par)?;
    Ok(match ow_par {
        1 => buffer_size_owpar1(fh, fw, iw, ich),
        2 => buffer_size_owpar2(fh, fw, iw, ich),
        n => ((fh - 1) * iw + fw + n - 2) * ich, // natural generalization
    })
}

/// FIFO slice plan for the partitioned window buffer (Figs. 7/9).
///
/// The buffer must be split so that all `(fw + ow_par - 1) * fh` window
/// elements can be read each cycle with single-ported FIFOs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlicePlan {
    /// Sizes of the FIFO slices in stream order.
    pub sizes: Vec<usize>,
    /// Forwarding stride: task T_i feeds slice i + stride (1 for ow_par=1,
    /// 2 for ow_par=2 — Fig. 9's activation-reuse wiring).
    pub forward_stride: usize,
}

impl SlicePlan {
    pub fn slices(&self) -> usize {
        self.sizes.len()
    }

    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }
}

/// Build the slice plan.  Distances in the depth-first stream:
/// within a window row, successive taps are `S1 = ich` apart; across rows
/// the gap is `S2 = (iw - fw_eff + 1) * ich` where `fw_eff = fw + ow_par-1`
/// is the widened window (Fig. 8 keeps `ow_par` computation windows).
pub fn slice_plan(
    fh: usize,
    fw: usize,
    iw: usize,
    ich: usize,
    ow_par: usize,
) -> Result<SlicePlan, WindowError> {
    validate(fh, fw, iw, ow_par)?;
    let fw_eff = fw + ow_par - 1;
    let s1 = ich;
    // Validated: fw_eff <= iw + 1, so this cannot underflow.
    let s2 = (iw + 1 - fw_eff) * ich;
    let mut sizes = Vec::new();
    for row in 0..fh {
        if row > 0 {
            sizes.push(s2);
        }
        for _ in 1..fw_eff {
            sizes.push(s1);
        }
    }
    // The first slice in stream order holds the newest activation; sizes
    // listed oldest-to-newest here.  One extra head slot per plan keeps the
    // in-flight element (implementation detail of the task chain).
    Ok(SlicePlan { sizes, forward_stride: ow_par })
}

/// Rate-aware window-buffer partitioning — the paper's stated future work
/// (Section III-F: "Optimizing the window buffer to reduce the required
/// partitioning in cases that allow a lower window generation rate is left
/// for future work").
///
/// The full `fh*fw_eff - 1`-way split exists only to read every window
/// element in a single cycle.  A layer whose computation task consumes one
/// window every `interval = ich * och_groups` cycles can time-multiplex up
/// to `interval` reads per physical FIFO, so adjacent slices merge until
/// each merged group still satisfies `reads_per_window <= interval`.
/// Fewer slices = fewer FIFOs = less control logic and LUTRAM
/// fragmentation, at zero throughput cost — quantified by the
/// `fig_buffering` bench ablation.
pub fn slice_plan_rate_aware(
    fh: usize,
    fw: usize,
    iw: usize,
    ich: usize,
    ow_par: usize,
    window_interval_cycles: usize,
) -> Result<SlicePlan, WindowError> {
    let full = slice_plan(fh, fw, iw, ich, ow_par)?;
    let interval = window_interval_cycles.max(1);
    if interval == 1 {
        return Ok(full);
    }
    // Merge up to `interval` adjacent slices per physical FIFO: the window
    // task then performs `group_len` sequential reads per window, which
    // still completes within the consumption interval.
    let mut sizes = Vec::new();
    let mut acc = 0usize;
    let mut count = 0usize;
    for &s in &full.sizes {
        acc += s;
        count += 1;
        if count == interval {
            sizes.push(acc);
            acc = 0;
            count = 0;
        }
    }
    if count > 0 {
        sizes.push(acc);
    }
    Ok(SlicePlan { sizes, forward_stride: full.forward_stride })
}

/// Receptive-field height/width of conv1's window back-projected through
/// conv0 (Eqs. 18–19, stride 1 as in the paper's derivation).
pub fn receptive_field(fh0: usize, fw0: usize, fh1: usize, fw1: usize) -> (usize, usize) {
    (fh1 + fh0 - 1, fw1 + fw0 - 1)
}

/// Skip-connection buffering of the *unoptimized* dataflow (Eq. 21): the
/// bypass branch must hold every activation whose receptive field overlaps
/// conv1's first window, i.e. `B_sc = [iw0*(rh0 - 1) + rw0] * ich0`.
pub fn skip_buffer_naive(
    fh0: usize,
    fw0: usize,
    iw0: usize,
    ich0: usize,
    fh1: usize,
    fw1: usize,
) -> usize {
    let (rh0, rw0) = receptive_field(fh0, fw0, fh1, fw1);
    (iw0 * (rh0 - 1) + rw0) * ich0
}

/// Skip-connection buffering of the *optimized* dataflow (Eq. 22): after
/// loop merge / temporal reuse + add fusion, producer and consumer run in
/// lockstep and the skip stream only needs conv1's window-buffer depth:
/// `B_sc = [(fh1-1)*iw1 + fw1 - 1] * ich1`.
pub fn skip_buffer_optimized(fh1: usize, fw1: usize, iw1: usize, ich1: usize) -> usize {
    buffer_size_owpar1(fh1, fw1, iw1, ich1)
}

/// The buffering reduction ratio R_sc (Eq. 23).
#[allow(clippy::too_many_arguments)]
pub fn skip_reduction_ratio(
    fh0: usize, fw0: usize, iw0: usize, ich0: usize,
    fh1: usize, fw1: usize, iw1: usize, ich1: usize,
) -> f64 {
    skip_buffer_optimized(fh1, fw1, iw1, ich1) as f64
        / skip_buffer_naive(fh0, fw0, iw0, ich0, fh1, fw1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn eq16_example() {
        // 3x3 filter over a 32-wide, 16-channel tensor.
        assert_eq!(buffer_size_owpar1(3, 3, 32, 16), ((2 * 32) + 2) * 16);
    }

    #[test]
    fn slice_plan_sums_to_buffer_size() {
        // The chain of slice distances spans first-to-last window element:
        // exactly B_i (minus nothing — Eq. 16 counts the same span).
        // Sampled over every supported ow_par, including the `n > 2`
        // "natural generalization" arm of buffer_size.
        forall("slice plan total == B_i span", 300, |rng| {
            let fh = rng.range_i64(1, 5) as usize;
            let fw = rng.range_i64(1, 5) as usize;
            let ow_par = rng.range_i64(1, 4) as usize;
            let iw = rng.range_i64((fw + ow_par) as i64, 64) as usize;
            let ich = rng.range_i64(1, 64) as usize;
            let plan = slice_plan(fh, fw, iw, ich, ow_par).unwrap();
            // Span of distances = ((fh-1)*iw + fw_eff - 1) * ich, which is
            // exactly the Eq. 16/17 buffer size for the widened window.
            let fw_eff = fw + ow_par - 1;
            let span = ((fh - 1) * iw + fw_eff - 1) * ich;
            assert_eq!(plan.total(), span);
            assert_eq!(plan.total(), buffer_size(fh, fw, iw, ich, ow_par).unwrap());
            // One slice per window-element transition: fh*(fw_eff-1) within
            // rows + (fh-1) across rows.
            assert_eq!(plan.slices(), fh * (fw_eff - 1) + (fh - 1));
        });
    }

    #[test]
    fn narrow_rows_yield_typed_errors_not_underflow() {
        // Regression: s2 = (iw - fw_eff + 1) * ich used to underflow (debug
        // panic / release wrap) whenever fw_eff = fw + ow_par - 1 > iw + 1 —
        // reachable for narrow late-stage feature maps (8-wide rows with
        // fw = 5 and a large `--ow-par`).  All three sizing entry points
        // must return the typed error instead.
        let too_wide = |r: Result<_, WindowError>| match r {
            Err(WindowError::TooWide { fw_eff, iw }) => (fw_eff, iw),
            other => panic!("expected TooWide, got {other:?}"),
        };
        // fw_eff = 5 + 6 - 1 = 10 > 8 + 1.
        assert_eq!(too_wide(slice_plan(3, 5, 8, 16, 6).map(|_| ())), (10, 8));
        assert_eq!(too_wide(buffer_size(3, 5, 8, 16, 6).map(|_| ())), (10, 8));
        assert_eq!(too_wide(slice_plan_rate_aware(3, 5, 8, 16, 6, 4).map(|_| ())), (10, 8));
        // Narrow row alone is fine as long as the widened window fits:
        // fw_eff = iw + 1 is the boundary (S2 = 0 — a direct wire).
        let plan = slice_plan(3, 5, 8, 2, 4).unwrap(); // fw_eff = 8 <= 9
        assert_eq!(plan.total(), buffer_size(3, 5, 8, 2, 4).unwrap());
        let boundary = slice_plan(3, 5, 8, 2, 5).unwrap(); // fw_eff = 9 = iw + 1
        assert!(boundary.sizes.contains(&0), "S2 slices collapse to wires");
        // Degenerate factors are rejected, not wrapped.
        assert!(matches!(
            buffer_size(0, 3, 32, 16, 1),
            Err(WindowError::Degenerate { .. })
        ));
        assert!(matches!(slice_plan(3, 3, 32, 16, 0), Err(WindowError::Degenerate { .. })));
    }

    #[test]
    fn paper_eq23_resnet20_first_blocks() {
        // Without downsample: iw0=iw1=32, ich0=ich1=16, 3x3 filters.
        let r = skip_reduction_ratio(3, 3, 32, 16, 3, 3, 32, 16);
        assert!((r - 0.5).abs() < 0.02, "R_sc = {r}, paper says 0.5");
        // With downsample: iw0=32, iw1=16, ich0=16, ich1=32.
        let r = skip_reduction_ratio(3, 3, 32, 16, 3, 3, 16, 32);
        assert!((r - 0.5).abs() < 0.02, "R_sc = {r}, paper says 0.5");
    }

    #[test]
    fn rate_aware_partitioning_reduces_slices_without_losing_capacity() {
        forall("rate-aware merge preserves capacity", 300, |rng| {
            let fh = rng.range_i64(2, 4) as usize;
            let iw = rng.range_i64(8, 40) as usize;
            let ich = rng.range_i64(1, 32) as usize;
            let interval = rng.range_i64(1, 12) as usize;
            let full = slice_plan(fh, fh, iw, ich, 2).unwrap();
            let merged = slice_plan_rate_aware(fh, fh, iw, ich, 2, interval).unwrap();
            assert_eq!(full.total(), merged.total(), "capacity preserved");
            assert_eq!(merged.slices(), full.slices().div_ceil(interval));
            assert!(merged.slices() <= full.slices());
        });
        // Unit rate (one window per cycle) must keep the full split.
        let full = slice_plan(3, 3, 32, 16, 2).unwrap();
        let same = slice_plan_rate_aware(3, 3, 32, 16, 2, 1).unwrap();
        assert_eq!(full, same);
    }

    #[test]
    fn naive_exceeds_optimized_everywhere() {
        forall("B_sc naive > optimized", 300, |rng| {
            let fh = rng.range_i64(2, 5) as usize;
            let iw = rng.range_i64(8, 64) as usize;
            let ich = rng.range_i64(1, 64) as usize;
            let naive = skip_buffer_naive(fh, fh, iw, ich, fh, fh);
            let opt = skip_buffer_optimized(fh, fh, iw, ich);
            assert!(naive > opt, "naive {naive} <= opt {opt}");
        });
    }
}
