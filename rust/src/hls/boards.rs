//! Target platforms (paper Table 2).
//!
//! Note: the paper's Table 2 swaps the LUT and FF columns (the xczu3eg has
//! 70,560 LUTs / 141,120 FFs, not the reverse — and Table 4's own
//! percentages confirm it: 46.4 kLUT at 65.8% ⇒ ≈70.5k total).  We store
//! the corrected values and document the fix here.

/// An FPGA target board.
#[derive(Debug, Clone, PartialEq)]
pub struct Board {
    pub name: &'static str,
    pub part: &'static str,
    pub luts: u32,
    pub ffs: u32,
    /// BRAM36 blocks (36 Kib = 4 KiB usable each, paper Section III-D).
    pub bram36: u32,
    pub dsps: u32,
    /// UltraRAM blocks (288 Kib = 32 KiB each); 0 when absent.
    pub urams: u32,
    /// Achieved fabric clock for our design, MHz (paper Table 3).
    pub clock_mhz: f64,
}

impl Board {
    /// N_PAR for the ILP: the paper sets it to the DSP count (Eq. 13,
    /// "during hardware generation, N_PAR is set to the number of DSPs").
    pub fn n_par(&self) -> u32 {
        self.dsps
    }

    /// Whether parameters live in URAM (KV260) or BRAM (Ultra96),
    /// paper Section III-D.
    pub fn uses_uram(&self) -> bool {
        self.urams > 0
    }
}

/// Avnet Ultra96-V2 (Zynq UltraScale+ ZU3EG).
pub const ULTRA96: Board = Board {
    name: "Ultra96",
    part: "xczu3eg",
    luts: 70_560,
    ffs: 141_120,
    bram36: 216,
    dsps: 360,
    urams: 0,
    clock_mhz: 214.0,
};

/// AMD/Xilinx Kria KV260 (Zynq UltraScale+ ZU5EV fabric).
pub const KV260: Board = Board {
    name: "KV260",
    part: "xczu5ev",
    luts: 117_120,
    ffs: 234_240,
    bram36: 144,
    dsps: 1_248,
    urams: 64,
    clock_mhz: 274.0,
};

/// All boards the paper evaluates.
pub const BOARDS: [&Board; 2] = [&ULTRA96, &KV260];

pub fn board_by_name(name: &str) -> Option<&'static Board> {
    match name.to_ascii_lowercase().as_str() {
        "ultra96" | "ultra96-v2" => Some(&ULTRA96),
        "kv260" | "kria" | "kria-kv260" => Some(&KV260),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_dsp_counts() {
        // Eq. 13 discussion: "360 and 1248 DSPs, respectively".
        assert_eq!(ULTRA96.n_par(), 360);
        assert_eq!(KV260.n_par(), 1248);
    }

    #[test]
    fn table4_percentages_back_out_lut_totals() {
        // ResNet8/Ultra96: 46.4 kLUT reported as 65.8 %.
        let frac = 46_400.0 / ULTRA96.luts as f64;
        assert!((frac - 0.658).abs() < 0.01, "got {frac}");
        // ResNet20/KV260: 81.2 kLUT reported as 69.4 %.
        let frac = 81_200.0 / KV260.luts as f64;
        assert!((frac - 0.694).abs() < 0.01, "got {frac}");
    }
}
