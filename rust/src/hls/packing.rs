//! DSP packing model (paper Section III-C, after Xilinx WP487 [38]).
//!
//! One DSP48E2 multiplies a 27-bit and an 18-bit operand and accumulates
//! into a 48-bit register.  Packing two int8 activations `a`, `d` into the
//! 27-bit port (`d` in the upper half, 18 bits apart) against one int8
//! weight `b` yields
//!
//! ```text
//!   M = (d*2^18 + a) * b = (d*b)*2^18 + (a*b)
//! ```
//!
//! i.e. two MACs per DSP per cycle — the paper's `ow_par = 2`.  Chained
//! accumulation keeps both products in 18-bit lanes of the 48-bit partial;
//! the lower lane's sign bleeds a borrow into the upper lane, which the
//! paper's per-stage correction (`- p_v[17]`) and final *restore* stage
//! undo.  Algebraically the running 48-bit value is exactly
//! `U*2^18 + V` with `U = Σ d_j b_j`, `V = Σ a_j b_j`; this module models
//! that arithmetic bit-exactly and enforces the paper's chain-length limit.
//!
//! Because of the 2 guard bits and the 1-bit restore headroom, at most
//! **7** packed DSPs can be chained (Section III-C); a 3x3 filter's 9 taps
//! therefore split into two chains (7 + 2) plus one combining adder.

/// Maximum packed-DSP chain length for 8-bit operands (paper: 7).
pub const MAX_CHAIN: usize = 7;

/// The 18-bit lane mask of the 48-bit accumulator.
const LANE_MASK: i64 = (1 << 18) - 1;

/// Pack two int8 activations into the 27-bit multiplier port.
/// Returns the signed integer value `d*2^18 + a` (fits in 27 bits).
#[inline]
pub fn pack_operands(a: i8, d: i8) -> i64 {
    ((d as i64) << 18) + (a as i64)
}

/// One packed-DSP stage: multiply the packed activations by weight `b` and
/// add to the previous 48-bit partial.  Panics (debug) on 48-bit overflow —
/// which cannot happen within [`MAX_CHAIN`].
#[inline]
pub fn dsp_stage(p_prev: i64, a: i8, d: i8, b: i8) -> i64 {
    let m = pack_operands(a, d) * (b as i64); // 27x18 multiply
    let p = p_prev + m; // 48-bit accumulate
    debug_assert!(
        p.abs() < (1i64 << 47),
        "48-bit accumulator overflow: {p}"
    );
    p
}

/// Decode the two lanes of a 48-bit partial: `(sum_d_b, sum_a_b)`.
///
/// This is the paper's *restore* stage: the lower lane is sign-extended
/// from 18 bits and the borrow it imposed on the upper lane is undone
/// (adding back `p_v[17]`).
#[inline]
pub fn decode_lanes(p: i64) -> (i32, i32) {
    let v_raw = p & LANE_MASK;
    // Sign-extend 18-bit lane.
    let v = if v_raw & (1 << 17) != 0 { v_raw - (1 << 18) } else { v_raw };
    let u = (p - v) >> 18;
    (u as i32, v as i32)
}

/// Run a full packed chain over up to [`MAX_CHAIN`] taps.
/// `taps[j] = (a_j, d_j, b_j)`; returns `(Σ d·b, Σ a·b)`.
pub fn packed_chain(taps: &[(i8, i8, i8)]) -> (i32, i32) {
    assert!(
        taps.len() <= MAX_CHAIN,
        "chain length {} exceeds the paper's limit {MAX_CHAIN}",
        taps.len()
    );
    let mut p = 0i64;
    for &(a, d, b) in taps {
        p = dsp_stage(p, a, d, b);
    }
    decode_lanes(p)
}

/// Chain plan for a filter with `taps` MACs: chain lengths + adders needed.
///
/// The paper splits 9 taps into two chains respecting the max length and
/// combines the partials in an additional stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainPlan {
    pub chains: Vec<usize>,
    /// Combining adder stages (chains - 1).
    pub extra_adders: usize,
    /// Total pipeline depth in stages (chains run in parallel; depth is the
    /// longest chain plus the adder tree).
    pub pipeline_depth: usize,
}

pub fn chain_plan(taps: usize) -> ChainPlan {
    let n_chains = taps.div_ceil(MAX_CHAIN);
    let mut chains = Vec::with_capacity(n_chains);
    let mut remaining = taps;
    for i in 0..n_chains {
        let len = remaining.div_ceil(n_chains - i).min(MAX_CHAIN).min(remaining);
        // Fill greedily (paper: 9 -> 7 + 2).
        let len = if i == 0 { remaining.min(MAX_CHAIN) } else { len };
        chains.push(len);
        remaining -= len;
    }
    // Redistribute leftovers if the greedy fill missed (taps > 7*n_chains
    // cannot happen by construction).
    assert_eq!(chains.iter().sum::<usize>(), taps);
    let extra_adders = n_chains - 1;
    let depth = chains.iter().copied().max().unwrap_or(0) + extra_adders;
    ChainPlan { chains, extra_adders, pipeline_depth: depth }
}

/// DSPs needed by one processing-element group computing `och_par` output
/// channels of a `taps`-tap filter (Eq. 9 context):
/// one DSP per tap per channel — independent of `ow_par` (that is the
/// whole point of packing: `ow_par = 2` doubles MACs/cycle at equal DSPs).
pub fn dsps_for(och_par: usize, taps: usize) -> usize {
    och_par * taps
}

/// MACs per cycle delivered by that group (paper Eq. 9):
/// `cp = k * och_par * ow_par`.
pub fn macs_per_cycle(och_par: usize, taps: usize, ow_par: usize) -> usize {
    och_par * taps * ow_par
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn single_stage_decodes_two_macs() {
        let (u, v) = packed_chain(&[(3, -5, 7)]);
        assert_eq!(v, 21); // a*b
        assert_eq!(u, -35); // d*b
    }

    #[test]
    fn chain_of_seven_is_exact() {
        forall("7-chain lanes == scalar sums", 2000, |rng| {
            let n = rng.range_i64(1, MAX_CHAIN as i64) as usize;
            let taps: Vec<(i8, i8, i8)> = (0..n)
                .map(|_| {
                    (
                        rng.range_i64(-128, 127) as i8,
                        rng.range_i64(-128, 127) as i8,
                        rng.range_i64(-128, 127) as i8,
                    )
                })
                .collect();
            let (u, v) = packed_chain(&taps);
            let su: i32 = taps.iter().map(|&(_, d, b)| d as i32 * b as i32).sum();
            let sv: i32 = taps.iter().map(|&(a, _, b)| a as i32 * b as i32).sum();
            assert_eq!(u, su);
            assert_eq!(v, sv);
        });
    }

    #[test]
    #[should_panic(expected = "exceeds the paper's limit")]
    fn chain_of_eight_rejected() {
        let taps = vec![(1i8, 1i8, 1i8); 8];
        packed_chain(&taps);
    }

    #[test]
    fn paper_3x3_split() {
        // 9 taps -> chains of 7 + 2, one combining adder (Fig. 5 bottom).
        let plan = chain_plan(9);
        assert_eq!(plan.chains, vec![7, 2]);
        assert_eq!(plan.extra_adders, 1);
        // 1x1 filter: single 1-stage chain, no adder.
        let plan = chain_plan(1);
        assert_eq!(plan.chains, vec![1]);
        assert_eq!(plan.extra_adders, 0);
    }

    #[test]
    fn packing_doubles_throughput_at_equal_dsps() {
        let dsps = dsps_for(8, 9);
        assert_eq!(dsps, 72);
        assert_eq!(macs_per_cycle(8, 9, 2), 2 * macs_per_cycle(8, 9, 1));
    }

    #[test]
    fn lane_decode_handles_negative_lower_lane() {
        // Single stage with a*b < 0: upper lane must not absorb the borrow.
        let p = dsp_stage(0, -128, 127, 127);
        let (u, v) = decode_lanes(p);
        assert_eq!(v, -16256);
        assert_eq!(u, 16129);
    }
}
