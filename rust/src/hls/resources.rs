//! FPGA resource estimation and resource closure (paper Table 4).
//!
//! DSP and memory counts are *structural* (derived from the configuration:
//! one DSP per tap per parallel channel, BRAM/URAM from buffer bytes and
//! port bandwidth).  LUT/FF counts use a linear regression calibrated on
//! the paper's own Table 4 rows (documented below) — the standard way to
//! predict HLS resource usage pre-synthesis.
//!
//! `fit_to_board` is the *resource closure loop*: Algorithm 1 alone only
//! constrains DSPs, but the paper's KV260/ResNet20 design stops at 50% DSP
//! because LUTs saturate first (69.4% at 626 DSPs, Table 4).  We model
//! that by shrinking the DSP budget until the whole estimate fits.

use anyhow::Result;

use crate::graph::Graph;
use crate::ilp::{solve, Allocation, LayerLoad};

use super::boards::Board;
use super::config::{configure, AcceleratorConfig};

/// LUT/FF regression constants, least-squares fit to all four of the
/// paper's Table 4 rows (see DESIGN.md §Resources):
///   LUT = A_L * DSPs + B_L * conv_tasks  (+ LUTRAM, computed structurally)
/// residuals < 8% on every row.
const LUT_PER_DSP: f64 = 85.0;
const LUT_PER_TASK: f64 = 1330.0;
const LUT_BASE: f64 = 0.0;
/// FFs track LUTs closely in the paper's rows (0.95–1.06x).
const FF_PER_LUT: f64 = 1.03;

/// BRAM36 usable bytes (paper Section III-D: "up to 4 KB each").
const BRAM_BYTES: usize = 4096;
/// URAM usable bytes ("32 KB of data each").
const URAM_BYTES: usize = 32 * 1024;
/// Distributed-RAM threshold: FIFOs at or below this depth map to LUTRAM.
const LUTRAM_MAX_DEPTH: usize = 1024;
/// LUTs per byte of distributed RAM (SRL/LUTRAM packing, 64 bits per LUT
/// in RAM64 mode, halved for addressing overhead).
const LUTS_PER_LUTRAM_BYTE: f64 = 0.25;

/// A resource utilization report (Table 4 row).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceReport {
    pub dsps: u64,
    pub bram36: u64,
    pub urams: u64,
    pub luts: u64,
    pub ffs: u64,
    /// LUTs spent as distributed RAM (subset of `luts`).
    pub lutram_luts: u64,
}

/// Routing/timing headroom: designs above ~80% LUT utilization do not
/// close timing at the paper's 274/214 MHz clocks (the paper's own
/// largest designs sit at 69-77% LUT plus 15-21% LUTRAM).
pub const LUT_CLOSURE_FRAC: f64 = 0.83;

impl ResourceReport {
    pub fn fits(&self, b: &Board) -> bool {
        self.dsps <= b.dsps as u64
            && self.bram36 <= b.bram36 as u64
            && self.urams <= b.urams as u64
            && (self.luts as f64) <= b.luts as f64 * LUT_CLOSURE_FRAC
            && self.ffs <= b.ffs as u64
    }

    pub fn utilization(&self, b: &Board) -> String {
        format!(
            "LUT {:.1}k ({:.1}%)  FF {:.1}k ({:.1}%)  DSP {} ({:.1}%)  BRAM {} ({:.1}%)  URAM {} ({:.1}%)",
            self.luts as f64 / 1e3,
            100.0 * self.luts as f64 / b.luts as f64,
            self.ffs as f64 / 1e3,
            100.0 * self.ffs as f64 / b.ffs as f64,
            self.dsps,
            100.0 * self.dsps as f64 / b.dsps as f64,
            self.bram36,
            100.0 * self.bram36 as f64 / b.bram36 as f64,
            self.urams,
            if b.urams > 0 { 100.0 * self.urams as f64 / b.urams as f64 } else { 0.0 },
        )
    }
}

/// Estimate resources for a configured accelerator.
pub fn estimate(cfg: &AcceleratorConfig) -> ResourceReport {
    let board = &cfg.board;
    let mut r = ResourceReport::default();
    let mut lutram_bytes = 0usize;
    let mut conv_tasks = 0usize;

    for l in cfg.convs.values() {
        conv_tasks += 1;
        r.dsps += l.dsps + l.merged_ds.as_ref().map_or(0, |m| m.dsps);

        // Parameter storage: URAM on boards that have it (Sec. III-D), with
        // enough banks for both capacity and the cw bytes/cycle bandwidth.
        // Both memories are dual-ported (URAM: 2x72-bit = 16 B/cycle,
        // BRAM36: 2x36-bit = 8 B/cycle); the parameter tasks replay from
        // their first-iteration cache (Sec. III-D), so both ports serve
        // reads in steady state.
        let pb = l.param_bytes + l.merged_ds.as_ref().map_or(0, |m| m.param_bytes);
        let bw = l.cw + l.merged_ds.as_ref().map_or(0, |m| m.cw);
        if board.uses_uram() {
            r.urams += (pb.div_ceil(URAM_BYTES)).max(bw.div_ceil(16)) as u64;
        } else {
            r.bram36 += (pb.div_ceil(BRAM_BYTES)).max(bw.div_ceil(8)) as u64;
        }

        // Window buffer slices: deep slices (the S2 row gaps) go to BRAM,
        // shallow ones (S1 = ich) to LUTRAM.
        for &d in &l.window.sizes {
            if d > LUTRAM_MAX_DEPTH {
                r.bram36 += d.div_ceil(BRAM_BYTES).max(1) as u64;
            } else {
                lutram_bytes += d;
            }
        }

        // Output stream FIFOs.
        let oc = l.out_stream.capacity();
        if oc > LUTRAM_MAX_DEPTH {
            r.bram36 += oc.div_ceil(BRAM_BYTES).max(1) as u64;
        } else {
            lutram_bytes += oc;
        }

        // Skip stream (optimized form): conv1's window-sized FIFO.
        if let Some(s) = &l.skip_in {
            let c = s.capacity();
            if c > LUTRAM_MAX_DEPTH {
                r.bram36 += c.div_ceil(BRAM_BYTES).max(1) as u64;
            } else {
                lutram_bytes += c;
            }
        }
    }

    // Naive-dataflow Add tasks: their (much larger) skip FIFOs.
    for a in cfg.adds.values() {
        for skip in &a.skips {
            r.bram36 += skip.div_ceil(BRAM_BYTES).max(1) as u64;
        }
        conv_tasks += 1; // an extra concurrent task with control logic
    }

    r.lutram_luts = (lutram_bytes as f64 * LUTS_PER_LUTRAM_BYTE) as u64;
    r.luts = (LUT_PER_DSP * r.dsps as f64 + LUT_PER_TASK * conv_tasks as f64 + LUT_BASE) as u64
        + r.lutram_luts;
    r.ffs = (r.luts as f64 * FF_PER_LUT) as u64;
    r
}

/// Resource closure: find the largest DSP budget whose full design fits
/// the board, then return (allocation, config, report).
///
/// Shrinks the budget geometrically (3% steps) — the allocation space is
/// quantized by the divisor constraint so fine steps are pointless.
pub fn fit_to_board(
    arch_name: &str,
    g: &Graph,
    loads: &[LayerLoad],
    board: &Board,
    ow_par: usize,
) -> Result<(Allocation, AcceleratorConfig, ResourceReport)> {
    let mut budget = board.n_par() as u64;
    let mut last_err = None;
    while budget >= loads.len() as u64 {
        match solve(loads, budget) {
            Some(alloc) => {
                let cfg = configure(arch_name, g, &alloc, board, ow_par)?;
                let rep = estimate(&cfg);
                if rep.fits(board) {
                    return Ok((alloc, cfg, rep));
                }
                last_err = Some(format!(
                    "budget {budget}: {}",
                    rep.utilization(board)
                ));
            }
            None => break,
        }
        budget = (budget as f64 * 0.97) as u64;
        if budget == 0 {
            break;
        }
    }
    anyhow::bail!(
        "no feasible design for {arch_name} on {} (last: {:?})",
        board.name,
        last_err
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::boards::{KV260, ULTRA96};
    use crate::ilp::loads_from_arch;
    use crate::models::{build_optimized_graph, default_exps, resnet20, resnet8};

    fn fit(arch_name: &str, board: &Board) -> (Allocation, AcceleratorConfig, ResourceReport) {
        let arch = if arch_name == "resnet8" { resnet8() } else { resnet20() };
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        let loads = loads_from_arch(&arch, 2);
        fit_to_board(&arch.name, &g, &loads, board, 2).unwrap()
    }

    #[test]
    fn all_four_designs_fit() {
        for arch in ["resnet8", "resnet20"] {
            for board in [&ULTRA96, &KV260] {
                let (_, cfg, rep) = fit(arch, board);
                assert!(rep.fits(board), "{arch}@{}: {}", board.name, rep.utilization(board));
                assert!(cfg.fps() > 500.0, "{arch}@{}: {} fps", board.name, cfg.fps());
            }
        }
    }

    #[test]
    fn table4_shape_resnet20_kv260_is_lut_bound() {
        // The paper's ResNet20/KV260 design uses only ~50% of DSPs because
        // LUTs close first; our closure must reproduce that *shape*.
        let (_, _, rep) = fit("resnet20", &KV260);
        let dsp_frac = rep.dsps as f64 / KV260.dsps as f64;
        let lut_frac = rep.luts as f64 / KV260.luts as f64;
        assert!(dsp_frac < 0.9, "dsp {dsp_frac}");
        assert!(lut_frac > dsp_frac, "LUTs should bind before DSPs: lut {lut_frac} dsp {dsp_frac}");
    }

    #[test]
    fn resnet8_ultra96_matches_paper_fps_band() {
        // Paper Table 3: ResNet8/Ultra96 = 12 971 FPS at 214 MHz.  Our
        // balanced divisor-quantized allocation reaches the same FPS with
        // fewer DSPs than the paper's 100% (their design spends extra DSPs
        // on adder trees/pool/fc that we model in LUTs) — the throughput,
        // not the DSP count, is the reproduction target.
        let (_, cfg, rep) = fit("resnet8", &ULTRA96);
        let ratio = cfg.fps() / 12_971.0;
        assert!((0.6..=1.6).contains(&ratio), "fps {} ratio {ratio}", cfg.fps());
        let dsp_frac = rep.dsps as f64 / ULTRA96.dsps as f64;
        assert!(dsp_frac > 0.3, "dsp {dsp_frac}");
    }
}
