//! Inter-task stream (FIFO) sizing — paper Section III-E.
//!
//! "To avoid stalling, all streams are sized appropriately by our
//! configuration Python script based on their type": parameter streams at
//! depth 2 (producer and consumer move one token per cycle), window-buffer
//! slices at their stream-distance sizes, and computation-task output
//! streams split into `ow_par` channels of depth `och_groups` to absorb
//! the burst of `och * ow_par` activations written per window position.

/// Kinds of streams in the generated design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamKind {
    /// parameter task -> computation task (weights), token = och_par values.
    Parameter,
    /// window buffer slice (FIFO between window tasks).
    WindowSlice,
    /// computation task output (activations), split into ow_par channels.
    Output,
    /// skip-connection stream into a fused conv1 (SkipInit input).
    Skip,
    /// top-level DMA in/out.
    Dma,
}

/// A sized stream instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamSpec {
    pub kind: StreamKind,
    /// Depth in tokens.
    pub depth: usize,
    /// Token width in activations (elements moved per push).
    pub token: usize,
    /// Parallel channels (ow_par for Output).
    pub channels: usize,
}

impl StreamSpec {
    /// Total buffered activations across channels.
    pub fn capacity(&self) -> usize {
        self.depth * self.token * self.channels
    }
}

/// Parameter stream: "since the producer and consumer write and read one
/// token per clock cycle, the stream size is 2."
pub fn parameter_stream(och_par: usize, taps: usize) -> StreamSpec {
    StreamSpec { kind: StreamKind::Parameter, depth: 2, token: och_par * taps, channels: 1 }
}

/// Computation-task output stream: `ow_par` channels, each a FIFO of
/// `och_groups = ceil(och / och_par)` tokens of `och_par` activations, so
/// a full burst (`och * ow_par` values) fits without stalling the pipeline
/// (the last group may be partially filled).
pub fn output_stream(och: usize, och_par: usize, ow_par: usize) -> StreamSpec {
    StreamSpec {
        kind: StreamKind::Output,
        depth: och.div_ceil(och_par),
        token: och_par,
        channels: ow_par,
    }
}

/// Skip stream into a fused conv1: depth = the optimized B_sc (Eq. 22),
/// i.e. conv1's own window-buffer size — producer (conv0) and consumer
/// (conv1) advance at the same rate after the graph optimization.
pub fn skip_stream(b_sc: usize) -> StreamSpec {
    StreamSpec { kind: StreamKind::Skip, depth: b_sc, token: 1, channels: 1 }
}

/// DMA stream (network input/output): double-buffered row of pixels.
pub fn dma_stream(row_elems: usize) -> StreamSpec {
    StreamSpec { kind: StreamKind::Dma, depth: 2, token: row_elems, channels: 1 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_streams_are_depth_two() {
        let s = parameter_stream(8, 9);
        assert_eq!(s.depth, 2);
        assert_eq!(s.token, 72);
    }

    #[test]
    fn output_stream_holds_full_burst() {
        let s = output_stream(64, 8, 2);
        assert_eq!(s.depth, 8); // och_groups
        assert_eq!(s.capacity(), 64 * 2);
    }

    #[test]
    fn partial_last_group_rounds_up() {
        // och = 64, och_par = 7 -> 10 groups, last one partially filled.
        let s = output_stream(64, 7, 2);
        assert_eq!(s.depth, 10);
        assert!(s.capacity() >= 64 * 2);
    }
}
